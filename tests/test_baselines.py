"""Baseline batched replays (GAM / FastSwap) vs the scalar oracle.

The ISSUE 8 contract: the directory-free baselines replay batched with
*bytewise* parity against :meth:`SystemModel.scalar_access` — identical
:class:`EpochStats`, bit-equal runtime / per-thread totals / latency
breakdown — across every regime (no-eviction vectorized decode, cache
pressure walking the oracle, the mixed case, and the degenerate
carried-in-M corner), for any chunk size, and with model state left
exactly as the scalar run leaves it (back-to-back runs stay in sync).
A golden-pinned regression locks every system's scalar semantics to the
pre-refactor emulator (``tests/data/system_goldens.json``; regenerate
with the snippet in that file's sibling ``make_goldens`` docstring
below), so the model extraction provably changed nothing.

Golden regeneration (only when semantics intentionally change)::

    PYTHONPATH=src python - <<'EOF'
    # see tests/test_baselines.py::GOLDENS for the cell grid
    EOF
"""

import json
from pathlib import Path

import numpy as np
import pytest

from repro.core import traces as T
from repro.core.emulator import DisaggregatedRack, run_workload

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - hypothesis is a [dev] extra
    HAVE_HYPOTHESIS = False

GOLDENS = json.loads(
    (Path(__file__).parent / "data" / "system_goldens.json").read_text())

STAT_FIELDS = (
    "accesses", "local_hits", "remote_fetches", "invalidations",
    "invalidated_pages", "false_invalidated_pages", "flushed_pages",
    "evicted_dirty", "evicted_clean", "faults", "splits", "merges",
)

BASELINES = ("gam", "fastswap")


def _trace(workload, threads, n, seed=11):
    if workload == "YCSB":
        return T.ycsb_trace("zipf", num_threads=threads, read_ratio=0.5,
                            accesses_per_thread=n, store_mb=4, seed=seed)
    return T.WORKLOADS[workload](num_threads=threads,
                                 accesses_per_thread=n)


def _pair(system, trace, opts=None, **kw):
    kw.setdefault("num_compute_blades", 2)
    kw.setdefault("threads_per_blade", 2)
    rs = DisaggregatedRack(system=system, engine="scalar", **kw).run(trace)
    rb = DisaggregatedRack(system=system, engine="batched",
                           engine_options=opts or {}, **kw).run(trace)
    return rs, rb


def _assert_exact(rs, rb):
    """The full bytewise-parity contract."""
    assert rs.stats == rb.stats
    assert rs.runtime_us == rb.runtime_us
    assert rs.total_thread_us == rb.total_thread_us
    assert rs.latency_breakdown_us == rb.latency_breakdown_us
    assert rb.engine == "batched" and rs.engine == "scalar"


# --------------------------------------------------------------------- #
# Deterministic scalar-vs-batched parity across workloads.
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("system", BASELINES)
@pytest.mark.parametrize("workload", ["TF", "GC", "YCSB"])
def test_parity_across_workloads(system, workload):
    rs, rb = _pair(system, _trace(workload, 8, 300),
                   num_compute_blades=4, threads_per_blade=2)
    _assert_exact(rs, rb)
    assert rb.stats.accesses == 2400


@pytest.mark.parametrize("system", BASELINES)
@pytest.mark.parametrize("chunk", [7, 64, 1000])
def test_parity_is_chunk_size_invariant(system, chunk):
    tr = _trace("YCSB", 6, 200)
    rs, rb = _pair(system, tr, opts={"chunk_size": chunk},
                   num_compute_blades=3, threads_per_blade=2)
    _assert_exact(rs, rb)


# --------------------------------------------------------------------- #
# Regimes: vectorized fast path, oracle walks under pressure, the mix.
# --------------------------------------------------------------------- #
def _engine_run(system, trace, opts=None, **kw):
    kw.setdefault("num_compute_blades", 2)
    kw.setdefault("threads_per_blade", 2)
    rack = DisaggregatedRack(system=system, engine="batched", **kw)
    eng = rack.model.make_batched_engine(**(opts or {}))
    return eng, eng.run(trace)


@pytest.mark.parametrize("system", BASELINES)
def test_safe_regime_runs_fully_vectorized(system):
    tr = _trace("YCSB", 4, 250)
    eng, rb = _engine_run(system, tr)
    assert eng.vectorized_accesses == rb.stats.accesses == 1000
    assert eng.walked_accesses == 0
    rs = DisaggregatedRack(system=system, engine="scalar",
                           num_compute_blades=2,
                           threads_per_blade=2).run(tr)
    _assert_exact(rs, rb)


@pytest.mark.parametrize("system", BASELINES)
def test_pressure_regime_walks_the_oracle_exactly(system):
    tr = T.uniform_trace(num_threads=4, read_ratio=0.6, sharing_ratio=0.5,
                         accesses_per_thread=250, working_set_pages=2000,
                         seed=5)
    kw = dict(cache_bytes_per_blade=1 << 14)  # 4 pages/blade
    eng, rb = _engine_run(system, tr, **kw)
    assert eng.walked_accesses > 0
    rs = DisaggregatedRack(system=system, engine="scalar",
                           num_compute_blades=2, threads_per_blade=2,
                           **kw).run(tr)
    _assert_exact(rs, rb)
    assert rb.stats.evicted_dirty + rb.stats.evicted_clean > 0


@pytest.mark.parametrize("system", BASELINES)
def test_mixed_regime_exercises_both_paths(system):
    tr = _trace("YCSB", 4, 400, seed=3)
    kw = dict(cache_bytes_per_blade=1 << 20)  # 256 pages/blade
    eng, rb = _engine_run(system, tr, opts={"chunk_size": 200}, **kw)
    assert eng.vectorized_accesses > 0 and eng.walked_accesses > 0
    rs = DisaggregatedRack(system=system, engine="scalar",
                           num_compute_blades=2, threads_per_blade=2,
                           **kw).run(tr)
    _assert_exact(rs, rb)


@pytest.mark.parametrize("system", BASELINES)
def test_back_to_back_runs_keep_state_in_sync(system):
    """Directory / cache / LRU state written back by a batched run must
    be exactly what the scalar oracle leaves — a second run over fresh
    traffic diverges otherwise."""
    t1 = _trace("YCSB", 4, 200, seed=21)
    t2 = _trace("YCSB", 4, 200, seed=22)
    kw = dict(num_compute_blades=2, threads_per_blade=2,
              cache_bytes_per_blade=1 << 19)
    racks = {e: DisaggregatedRack(system=system, engine=e, **kw)
             for e in ("scalar", "batched")}
    racks["scalar"].run(t1)
    racks["batched"].run(t1)
    rs = racks["scalar"].run(t2)
    rb = racks["batched"].run(t2)
    assert rs.stats == rb.stats
    assert rs.runtime_us == rb.runtime_us
    assert rs.latency_breakdown_us == rb.latency_breakdown_us


def test_gam_batched_counts_invalidations():
    """Sharing-heavy traffic drives the software-DSM invalidation path
    (write on S, read on foreign M) through the vectorized decode."""
    tr = T.uniform_trace(num_threads=8, read_ratio=0.5, sharing_ratio=1.0,
                         accesses_per_thread=200, working_set_pages=64,
                         seed=9)
    rs, rb = _pair("gam", tr, num_compute_blades=4, threads_per_blade=2)
    _assert_exact(rs, rb)
    assert rb.stats.invalidations > 0


def test_fastswap_blades_stay_independent():
    """FastSwap has no coherence: per-blade stats add up regardless of
    sharing, and no invalidations are ever counted."""
    tr = T.uniform_trace(num_threads=8, read_ratio=0.5, sharing_ratio=1.0,
                         accesses_per_thread=200, working_set_pages=64,
                         seed=9)
    rs, rb = _pair("fastswap", tr, num_compute_blades=4,
                   threads_per_blade=2)
    _assert_exact(rs, rb)
    assert rb.stats.invalidations == 0


# --------------------------------------------------------------------- #
# Model extraction is semantics-preserving: pre-refactor goldens.
# --------------------------------------------------------------------- #
@pytest.mark.parametrize(
    "cell", GOLDENS,
    ids=[f"{c['system']}-{c['workload']}" for c in GOLDENS])
def test_scalar_semantics_match_pre_refactor_goldens(cell):
    r = run_workload(cell["system"], cell["workload"],
                     num_compute_blades=cell["num_compute_blades"],
                     threads_per_blade=cell["threads_per_blade"],
                     accesses_per_thread=cell["accesses_per_thread"])
    for f in STAT_FIELDS:
        assert getattr(r.stats, f) == cell["stats"][f], f
    np.testing.assert_allclose(r.runtime_us, cell["runtime_us"],
                               rtol=1e-12)
    np.testing.assert_allclose(r.total_thread_us, cell["total_thread_us"],
                               rtol=1e-12)
    np.testing.assert_allclose(r.performance, cell["performance"],
                               rtol=1e-12)
    for k, v in cell["latency_breakdown_us"].items():
        np.testing.assert_allclose(r.latency_breakdown_us[k], v,
                                   rtol=1e-12, err_msg=k)


# --------------------------------------------------------------------- #
# The loud-fallback benchmark contract (benchmarks/common.py).
# --------------------------------------------------------------------- #
def test_run_workload_with_engine_refusal_is_loud():
    from benchmarks.common import run_workload_with_engine

    with pytest.raises(SystemExit, match="refused"):
        run_workload_with_engine("batched", "mind", "TF",
                                 num_compute_blades=25,
                                 threads_per_blade=1,
                                 accesses_per_thread=20,
                                 splitting_enabled=False)


def test_run_workload_with_engine_explicit_fallback():
    from benchmarks.common import run_workload_with_engine

    r = run_workload_with_engine("batched", "mind", "TF",
                                 allow_scalar_fallback=True,
                                 num_compute_blades=25,
                                 threads_per_blade=1,
                                 accesses_per_thread=20,
                                 splitting_enabled=False)
    assert r.engine == "scalar"


def test_run_workload_with_engine_baselines_run_batched():
    from benchmarks.common import run_workload_with_engine

    for system in BASELINES:
        r = run_workload_with_engine("batched", system, "TF",
                                     num_compute_blades=2,
                                     threads_per_blade=2,
                                     accesses_per_thread=50)
        assert r.engine == "batched"


# --------------------------------------------------------------------- #
# Property-based parity sweep.
# --------------------------------------------------------------------- #
if HAVE_HYPOTHESIS:

    @settings(max_examples=15, deadline=None)
    @given(
        system=st.sampled_from(BASELINES),
        nb=st.integers(1, 4),
        tpb=st.integers(1, 3),
        n=st.integers(20, 200),
        seed=st.integers(0, 2 ** 16),
        chunk=st.integers(8, 512),
        cache_pow=st.sampled_from([14, 19, 29]),
    )
    def test_parity_property(system, nb, tpb, n, seed, chunk, cache_pow):
        tr = T.ycsb_trace("zipf", num_threads=nb * tpb, read_ratio=0.5,
                          accesses_per_thread=n, store_mb=4, seed=seed)
        rs, rb = _pair(system, tr, opts={"chunk_size": chunk},
                       num_compute_blades=nb, threads_per_blade=tpb,
                       cache_bytes_per_blade=1 << cache_pow)
        _assert_exact(rs, rb)

    @settings(max_examples=10, deadline=None)
    @given(
        system=st.sampled_from(BASELINES),
        read_ratio=st.sampled_from([0.0, 0.5, 1.0]),
        sharing=st.sampled_from([0.0, 0.5, 1.0]),
        seed=st.integers(0, 2 ** 16),
    )
    def test_parity_property_uniform(system, read_ratio, sharing, seed):
        tr = T.uniform_trace(num_threads=6, read_ratio=read_ratio,
                             sharing_ratio=sharing,
                             accesses_per_thread=150,
                             working_set_pages=500, seed=seed)
        rs, rb = _pair(system, tr, num_compute_blades=3,
                       threads_per_blade=2,
                       cache_bytes_per_blade=1 << 21)
        _assert_exact(rs, rb)
