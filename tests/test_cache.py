"""BladePageCache behaviour: strict LRU, eviction order, and the
shadow structure the batched engine's cache-occupancy pre-pass replays.

The cache is *strict LRU* (not CLOCK — see the module docstring of
src/repro/core/cache.py): every touch/insert/dirtying moves the page to
the MRU end, and capacity eviction pops the LRU end.  ``lru_pages()``
exposes that order coldest-first; the pre-pass's
``BladeCacheShadow`` must evict the exact same victims in the exact
same order, which is what makes batched cache-eviction replay exact.
"""

import numpy as np

from repro.core.cache import BladePageCache
from repro.core.types import PAGE_SIZE, EpochStats
from repro.dataplane.tables import BladeCacheShadow


def _pg(i: int) -> int:
    return i * PAGE_SIZE


def test_lru_pages_exposes_eviction_order():
    c = BladePageCache(0, 4 * PAGE_SIZE)
    for i in range(4):
        c.insert(_pg(i), dirty=(i % 2 == 1))
    assert [p for p, _ in c.lru_pages()] == [_pg(0), _pg(1), _pg(2), _pg(3)]
    # A touch refreshes recency; mark_dirty does too.
    c.touch(_pg(0))
    c.mark_dirty(_pg(1))
    assert [p for p, _ in c.lru_pages()] == [_pg(2), _pg(3), _pg(0), _pg(1)]
    assert dict(c.lru_pages())[_pg(1)] is True
    # Evictions consume lru_pages() front-to-back.
    expected_victims = [p for p, _ in c.lru_pages()]
    for j, vp in enumerate(expected_victims):
        c.insert(_pg(100 + j), dirty=False)
        assert vp not in c.pages
    assert c.evicted_dirty == 2 and c.evicted_clean == 2


def test_insert_returns_dirty_writebacks_and_counts_stats():
    c = BladePageCache(0, 2 * PAGE_SIZE)
    c.stats = EpochStats()
    assert c.insert(_pg(0), dirty=True) == 0
    assert c.insert(_pg(1), dirty=False) == 0
    # Evicts page 0 (dirty) -> one write-back reported.
    assert c.insert(_pg(2), dirty=False) == 1
    # Evicts page 1 (clean) -> no write-back.
    assert c.insert(_pg(3), dirty=False) == 0
    assert (c.evicted_dirty, c.evicted_clean) == (1, 1)
    assert (c.stats.evicted_dirty, c.stats.evicted_clean) == (1, 1)


def test_shadow_matches_bladepagecache_eviction_order(rng):
    """Oracle test backing the pre-pass: drive BladePageCache and
    BladeCacheShadow with the same access/invalidation stream and
    require identical membership, LRU order and eviction events."""
    cap = 6
    c = BladePageCache(0, cap * PAGE_SIZE)
    s = BladeCacheShadow(cap)
    shadow_evicted: list = []
    oracle_evicted: list = []
    for step in range(2000):
        if step % 97 == 13:  # region invalidation drops a page range
            base = int(rng.integers(0, 24))
            length = int(rng.integers(1, 8))
            c.invalidate_region(_pg(base), length * PAGE_SIZE, None)
            s.drop_range(base, base + length)
            continue
        page = int(rng.integers(0, 32))
        dirty = bool(rng.integers(0, 2))
        before = dict(c.pages)
        flushed = c.insert(_pg(page), dirty)
        evicted = [p for p in before if p not in c.pages]
        oracle_evicted += [(p // PAGE_SIZE, before[p]) for p in evicted]
        assert flushed == sum(1 for p in evicted if before[p])
        shadow_evicted += list(s.insert_or_touch(page, dirty))
        assert sorted(s.pages) == sorted(p // PAGE_SIZE for p in c.pages)
        assert [p // PAGE_SIZE for p, _ in c.lru_pages()] == list(s.pages)
        assert [d for _, d in c.lru_pages()] == list(s.pages.values())
    assert oracle_evicted == shadow_evicted
    assert shadow_evicted  # the stream actually exercised evictions


def test_shadow_word_index_stays_consistent():
    s = BladeCacheShadow(4)
    for p in (0, 31, 32, 95):
        s.insert_or_touch(p, False)
    assert s.occupancy == 4
    s.drop_range(0, 33)  # drops 0, 31, 32 across two words
    assert sorted(s.pages) == [95]
    assert set(s.words) == {2}
    # Eviction cleans the word buckets too.
    for p in (1, 2, 3, 4):
        s.insert_or_touch(p, False)
    assert 95 not in s.pages and sorted(s.pages) == [1, 2, 3, 4]
    assert all(all(q in s.pages for q in b) for b in s.words.values())
