"""Test fixtures.  NOTE: no XLA_FLAGS here on purpose — smoke tests and
benches must see the single real CPU device; only launch/dryrun.py forces
512 placeholder devices (and it does so before importing jax)."""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
