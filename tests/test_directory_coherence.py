"""Directory + MSI coherence: protocol transitions, invariants, false
invalidations, capacity pressure."""

import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.cache import BladePageCache
from repro.core.coherence import CoherenceEngine
from repro.core.directory import CacheDirectory
from repro.core.types import (
    PAGE_SIZE,
    AccessType,
    MemAccess,
    MSIState,
    SwitchResources,
)

BASE = 1 << 40


def make_engine(nblades=4, max_entries=30_000, initial_log2=14,
                eviction="lru"):
    d = CacheDirectory(initial_region_log2=initial_log2,
                       resources=SwitchResources(max_directory_entries=max_entries),
                       eviction=eviction)
    caches = {b: BladePageCache(b, 1 << 20) for b in range(nblades)}
    return CoherenceEngine(d, caches), d, caches


def acc(engine, blade, addr, write):
    return engine.access(MemAccess(blade, 1, addr,
                                   AccessType.WRITE if write else AccessType.READ))


def test_read_miss_I_to_S():
    e, d, c = make_engine()
    acts, rec = acc(e, 0, BASE, write=False)
    assert rec.kind == "I->S"
    assert acts.fetch_from_memory
    entry = d.lookup(BASE)
    assert entry.state == MSIState.S and entry.sharers == 0b1


def test_write_miss_I_to_M():
    e, d, c = make_engine()
    acts, rec = acc(e, 1, BASE, write=True)
    assert rec.kind == "I->M"
    entry = d.lookup(BASE)
    assert entry.state == MSIState.M and entry.owner == 1


def test_S_to_M_invalidates_sharers_parallel():
    e, d, c = make_engine()
    acc(e, 0, BASE, write=False)
    acc(e, 1, BASE, write=False)
    acc(e, 2, BASE, write=False)
    acts, rec = acc(e, 3, BASE, write=True)
    assert rec.kind == "S->M"
    assert rec.parallel_invalidation  # Fig. 8: ~9us path
    assert acts.invalidate == 0b0111  # all other sharers multicast
    entry = d.lookup(BASE)
    assert entry.state == MSIState.M and entry.owner == 3
    # sharers' cached copies dropped
    for b in range(3):
        assert not c[b].has(BASE)


def test_M_to_S_sequential_owner_flush():
    e, d, c = make_engine()
    acc(e, 0, BASE, write=True)
    acts, rec = acc(e, 1, BASE, write=False)
    assert rec.kind == "M->S"
    assert rec.sequential_invalidation  # Fig. 8: ~18us path
    assert acts.fetch_from_owner == 0


def test_owner_rereads_locally():
    e, d, c = make_engine()
    acc(e, 0, BASE, write=True)
    acts, _ = acc(e, 0, BASE, write=False)
    assert acts.hit_local


def test_false_invalidation_counting():
    """Pages cached in the same region (≠ requested page) count as false
    invalidations when the region is invalidated (§4.3.1)."""
    e, d, c = make_engine(initial_log2=16)  # 64 KB regions = 16 pages
    for i in range(8):  # blade 0 caches 8 pages of one region
        acc(e, 0, BASE + i * PAGE_SIZE, write=True)
    before = e.stats.false_invalidated_pages
    acc(e, 1, BASE, write=True)  # invalidates the whole region at blade 0
    assert e.stats.false_invalidated_pages - before == 7  # 8 minus requested


def test_prepopulation_gives_owner_local_access():
    e, d, c = make_engine()
    e.prepopulate(BASE, 4 * PAGE_SIZE, owner_blade=2)
    acts, _ = acc(e, 2, BASE, write=True)
    assert acts.hit_local  # zero-fill, no fetch (§4.4 p-local)
    acts2, _ = acc(e, 0, BASE, write=False)
    assert not acts2.hit_local  # other blades trigger coherence


def test_capacity_eviction_invalidates_sharers():
    e, d, c = make_engine(max_entries=4, initial_log2=14)
    for i in range(8):
        acc(e, 0, BASE + i * (1 << 14), write=False)
    assert d.num_entries() <= 4
    assert d.capacity_evictions > 0


@given(
    ops=st.lists(
        st.tuples(st.integers(0, 3), st.integers(0, 63), st.booleans()),
        min_size=1, max_size=300,
    ),
    max_entries=st.integers(2, 12),
)
@settings(max_examples=50, deadline=None)
def test_lru_eviction_matches_scan_oracle(ops, max_entries):
    """ISSUE 2 property: the O(1) LRU eviction structure picks the exact
    victims the seed's O(n) scan picked (coldest Invalid entry first,
    else coldest overall) on randomized install/access sequences, so the
    directory contents stay byte-identical throughout."""
    e_lru, d_lru, _ = make_engine(max_entries=max_entries, eviction="lru")
    e_scan, d_scan, _ = make_engine(max_entries=max_entries, eviction="scan")
    for i, (blade, page, write) in enumerate(ops):
        addr = BASE + page * PAGE_SIZE
        acc(e_lru, blade, addr, write)
        acc(e_scan, blade, addr, write)
        assert list(d_lru.entries.keys()) == list(d_scan.entries.keys()), i
        assert d_lru.lru_keys() == d_scan.lru_keys(), i
        assert d_lru.capacity_evictions == d_scan.capacity_evictions, i
    for k, e1 in d_lru.entries.items():
        e2 = d_scan.entries[k]
        assert (e1.state, e1.sharers, e1.owner) == (e2.state, e2.sharers, e2.owner)


def test_export_recency_is_coldest_first_rank():
    e, d, _ = make_engine()
    for i in range(4):
        acc(e, 0, BASE + i * (1 << 14), write=False)
    acc(e, 1, BASE, write=False)  # re-touch the first region
    rows = d.export_tables()
    ranks = d.export_recency()
    order = [k for k, _ in sorted(
        (( (r[0], r[1]), rk) for r, rk in zip(rows, ranks)),
        key=lambda kv: kv[1])]
    assert order == d.lru_keys()
    assert order[-1] == (BASE, 14)  # most recently touched


@given(
    ops=st.lists(
        st.tuples(st.integers(0, 3), st.integers(0, 15), st.booleans()),
        min_size=1, max_size=200,
    )
)
@settings(max_examples=50, deadline=None)
def test_msi_invariants_random_traffic(ops):
    """Property: single-writer/multi-reader invariant always holds, and a
    page cached dirty at a blade implies that blade owns the region."""
    e, d, caches = make_engine()
    for blade, page, write in ops:
        acc(e, blade, BASE + page * PAGE_SIZE, write)
        e.check_invariants()
    # dirty page => its region is M-owned by that blade
    for b, cache in caches.items():
        for pg, dirty in cache.pages.items():
            if dirty:
                entry = d.lookup(pg)
                assert entry is not None
                assert entry.state == MSIState.M and entry.owner == b
