"""HLO cost parser correctness + chunked linear recurrence oracle tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.distributed.hlo_analysis import analyze_hlo_text
from repro.models.linear_recurrence import (
    chunked_linear_attention,
    recurrent_step,
)


# ------------------------------------------------------------------ #
# HLO parser: trip-count-aware FLOPs on known computations.
# ------------------------------------------------------------------ #
def _flops_of(fn, *specs):
    comp = jax.jit(fn).lower(*specs).compile()
    return analyze_hlo_text(comp.as_text()).flops


def test_scan_matmul_flops_exact():
    def f(x, ws):
        def body(c, w):
            return c @ w, None
        return jax.lax.scan(body, x, ws)[0]

    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    ws = jax.ShapeDtypeStruct((7, 256, 256), jnp.float32)
    got = _flops_of(f, x, ws)
    assert got == 7 * 2 * 128 * 256 * 256


def test_nested_scan_flops_exact():
    def g(x, ws):
        def outer(c, grp):
            def inner(c2, w):
                return jnp.tanh(c2 @ w), None
            return jax.lax.scan(inner, c, grp)[0], None
        return jax.lax.scan(outer, x, ws)[0]

    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((3, 4, 128, 128), jnp.float32)
    assert _flops_of(g, x, ws) == 12 * 2 * 64 * 128 * 128


def test_grad_flops_counted():
    def f(x, w):
        def body(c, wi):
            return c @ wi, None
        return (jax.lax.scan(body, x, w)[0] ** 2).sum()

    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((5, 128, 128), jnp.float32)
    got = _flops_of(jax.grad(f, argnums=1), x, w)
    assert got == 3 * 5 * 2 * 64 * 128 * 128  # fwd + dx + dw


def test_batched_einsum_flops():
    def e(a, b):
        return jnp.einsum("bhqd,bhkd->bhqk", a, b)

    a = jax.ShapeDtypeStruct((2, 4, 64, 32), jnp.float32)
    b = jax.ShapeDtypeStruct((2, 4, 96, 32), jnp.float32)
    assert _flops_of(e, a, b) == 2 * 2 * 4 * 64 * 96 * 32


# ------------------------------------------------------------------ #
# Chunked linear recurrence vs naive sequential (mLSTM/Mamba2 substrate).
# ------------------------------------------------------------------ #
def _naive(q, k, v, la, lb, norm):
    B, H, T, Dk = q.shape
    Dv = v.shape[-1]
    S = np.zeros((B, H, Dk, Dv))
    N = np.zeros((B, H, Dk))
    ys = np.zeros((B, H, T, Dv))
    for t in range(T):
        a = np.exp(la[:, :, t])[..., None, None]
        bb = np.exp(lb[:, :, t])[..., None, None]
        S = S * a + bb * (k[:, :, t, :, None] * v[:, :, t, None, :])
        N = N * a[..., 0] + bb[..., 0] * k[:, :, t]
        y = np.einsum("bhd,bhdv->bhv", q[:, :, t], S)
        if norm:
            den = np.einsum("bhd,bhd->bh", q[:, :, t], N)
            y = y / np.maximum(np.abs(den), 1.0)[..., None]
        ys[:, :, t] = y
    return ys, S, N


@given(
    t_log=st.integers(3, 6),
    chunk_log=st.integers(1, 4),
    dk=st.sampled_from([4, 8]),
    dv=st.sampled_from([4, 16]),
    norm=st.booleans(),
    seed=st.integers(0, 100),
)
@settings(max_examples=25, deadline=None)
def test_chunked_matches_naive(t_log, chunk_log, dk, dv, norm, seed):
    t, c = 1 << t_log, 1 << min(chunk_log, t_log)
    rng = np.random.default_rng(seed)
    q = rng.standard_normal((1, 2, t, dk)).astype(np.float32) * 0.3
    k = rng.standard_normal((1, 2, t, dk)).astype(np.float32) * 0.3
    v = rng.standard_normal((1, 2, t, dv)).astype(np.float32)
    la = -np.abs(rng.standard_normal((1, 2, t)).astype(np.float32)) * 0.3
    lb = -np.abs(rng.standard_normal((1, 2, t)).astype(np.float32)) * 0.5
    y, s_fin, n_fin = chunked_linear_attention(
        jnp.array(q), jnp.array(k), jnp.array(v), jnp.array(la),
        jnp.array(lb), chunk_size=c, normalize=norm)
    ys, S, N = _naive(q, k, v, la, lb, norm)
    np.testing.assert_allclose(np.asarray(y), ys, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s_fin), S, rtol=2e-4, atol=2e-4)


def test_decode_step_continues_prefill_state():
    """chunked(T) == chunked(T-1) + recurrent_step — the serve-path glue."""
    rng = np.random.default_rng(0)
    B, H, T, Dk, Dv = 1, 2, 17, 8, 8  # prefill 16 (2 chunks) + 1 decode
    q = rng.standard_normal((B, H, T, Dk)).astype(np.float32) * 0.3
    k = rng.standard_normal((B, H, T, Dk)).astype(np.float32) * 0.3
    v = rng.standard_normal((B, H, T, Dv)).astype(np.float32)
    la = -np.abs(rng.standard_normal((B, H, T))).astype(np.float32) * 0.3
    lb = -np.abs(rng.standard_normal((B, H, T))).astype(np.float32) * 0.5

    y_full, s_full, n_full = chunked_linear_attention(
        jnp.array(q), jnp.array(k), jnp.array(v), jnp.array(la),
        jnp.array(lb), chunk_size=8, normalize=True)
    y_pre, s_pre, n_pre = chunked_linear_attention(
        jnp.array(q[:, :, :T - 1]), jnp.array(k[:, :, :T - 1]),
        jnp.array(v[:, :, :T - 1]), jnp.array(la[:, :, :T - 1]),
        jnp.array(lb[:, :, :T - 1]), chunk_size=8, normalize=True)
    y_t, s_t, n_t = recurrent_step(
        jnp.array(q[:, :, -1]), jnp.array(k[:, :, -1]),
        jnp.array(v[:, :, -1]), jnp.array(la[:, :, -1]),
        jnp.array(lb[:, :, -1]), s_pre, n_pre, normalize=True)
    np.testing.assert_allclose(np.asarray(y_t), np.asarray(y_full[:, :, -1]),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s_t), np.asarray(s_full),
                               rtol=1e-4, atol=1e-4)
