"""Batched data-plane engine vs the scalar emulator oracle.

The contract (ISSUE 1, extended by ISSUEs 2 and 3): the batched engine
must produce *identical* coherence statistics and runtimes for every
mind* system — including traces with directory capacity evictions
(regions > ``max_directory_entries``), blade page-cache capacity
evictions (working set > a blade's cache) and Bounded-Splitting epochs,
whose boundaries the engine lands on exactly; the conflict scheduler
must serialize same-region packets and keep waves conflict-free.  The
no-switch baselines replay through their own batched engines
(:mod:`repro.dataplane.baselines`, covered by ``test_baselines.py``);
the only refusals left are the packed-kernel-output bounds, still loud
rather than silently diverging.
"""

import numpy as np
import pytest

from repro.core import traces as T
from repro.core.emulator import DisaggregatedRack, run_workload
from repro.dataplane import (
    UnsupportedByBatchedEngine,
    build_wave_schedule,
)
from repro.dataplane.tables import build_page_map

STAT_FIELDS = (
    "accesses", "local_hits", "remote_fetches", "invalidations",
    "invalidated_pages", "false_invalidated_pages", "flushed_pages",
    "evicted_dirty", "evicted_clean", "faults",
)


def _zipf_trace(threads=4):
    return T.ycsb_trace("zipf", num_threads=threads, read_ratio=0.5,
                        accesses_per_thread=250, store_mb=4, seed=11)


def _uniform_trace(threads=4):
    return T.uniform_trace(num_threads=threads, read_ratio=0.7,
                           sharing_ratio=0.5, accesses_per_thread=250,
                           working_set_pages=2000, seed=5)


def _pair(system, trace, lanes=4, **kw):
    kw.setdefault("num_compute_blades", 2)
    kw.setdefault("threads_per_blade", 2)
    kw.setdefault("splitting_enabled", False)
    rs = DisaggregatedRack(system=system, engine="scalar", **kw).run(trace)
    rb = DisaggregatedRack(system=system, engine="batched",
                           engine_options={"lanes": lanes}, **kw).run(trace)
    return rs, rb


# --------------------------------------------------------------------- #
# Parity: identical coherence stats + matching runtimes (ISSUE criteria).
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("system", ["mind", "mind-pso", "mind-pso+"])
@pytest.mark.parametrize("workload", ["zipfian", "uniform"])
def test_parity_coherence_stats_and_runtime(system, workload):
    trace = _zipf_trace() if workload == "zipfian" else _uniform_trace()
    rs, rb = _pair(system, trace)
    for f in STAT_FIELDS:
        assert getattr(rs.stats, f) == getattr(rb.stats, f), f
    assert rb.engine == "batched" and rs.engine == "scalar"
    np.testing.assert_allclose(rb.runtime_us, rs.runtime_us, rtol=1e-6)
    np.testing.assert_allclose(rb.total_thread_us, rs.total_thread_us,
                               rtol=1e-6)
    for k, v in rs.latency_breakdown_us.items():
        np.testing.assert_allclose(rb.latency_breakdown_us[k], v, rtol=1e-6,
                                   err_msg=k)


def test_parity_holds_for_any_lane_count():
    trace = _zipf_trace()
    rs, _ = _pair("mind", trace)
    for lanes in (1, 3, 8):
        _, rb = _pair("mind", trace, lanes=lanes)
        for f in STAT_FIELDS:
            assert getattr(rs.stats, f) == getattr(rb.stats, f), (lanes, f)
        np.testing.assert_allclose(rb.runtime_us, rs.runtime_us, rtol=1e-6)


def test_parity_transition_mix():
    """Same multiset of transition kinds + latencies, not just totals."""
    rs, rb = _pair("mind", _zipf_trace())
    assert set(rs.transition_latencies) == set(rb.transition_latencies)
    for k, v in rs.transition_latencies.items():
        w = rb.transition_latencies[k]
        assert len(v) == len(w), k
        np.testing.assert_allclose(sorted(v), sorted(w), rtol=1e-5,
                                   err_msg=k)


def test_parity_small_chunks_cross_state():
    """Directory/cache state must survive chunk boundaries intact."""
    trace = _zipf_trace()
    rs, _ = _pair("mind", trace)
    rb = DisaggregatedRack(
        system="mind", num_compute_blades=2, threads_per_blade=2,
        splitting_enabled=False, engine="batched",
        engine_options={"chunk_size": 128}).run(trace)
    for f in STAT_FIELDS:
        assert getattr(rs.stats, f) == getattr(rb.stats, f), f
    np.testing.assert_allclose(rb.runtime_us, rs.runtime_us, rtol=1e-6)


def test_epoch_splitting_exact_timing():
    """Bounded-Splitting epochs fire at exactly the access the scalar
    oracle fires them at (the engine shrinks batches to land on the
    boundary), so multi-epoch replay is stat-identical — the ISSUE 2
    contract replacing the old batch-granular drift tolerance."""
    trace = T.ycsb_trace("zipf", num_threads=4, read_ratio=0.5,
                         accesses_per_thread=600, store_mb=4, seed=7)
    kw = dict(num_compute_blades=2, threads_per_blade=2, epoch_us=4000.0)
    rs = DisaggregatedRack(system="mind", engine="scalar", **kw).run(trace)
    rb = DisaggregatedRack(system="mind", engine="batched", **kw).run(trace)
    assert rs.directory_timeline and rb.directory_timeline
    assert rs.directory_timeline == rb.directory_timeline
    assert len(rs.epoch_reports) == len(rb.epoch_reports)
    for a, b in zip(rs.epoch_reports, rb.epoch_reports):
        assert (a.splits, a.merges, a.directory_entries) == (
            b.splits, b.merges, b.directory_entries)
    for f in STAT_FIELDS:
        assert getattr(rs.stats, f) == getattr(rb.stats, f), f
    np.testing.assert_allclose(rb.runtime_us, rs.runtime_us, rtol=1e-9)


def test_mean_access_us_not_scaled_by_thread_count():
    """The satellite fix: mean access latency is busy-time / accesses,
    not runtime * threads / accesses."""
    r = run_workload("mind", "GC", num_compute_blades=2, threads_per_blade=4,
                     accesses_per_thread=300)
    assert r.total_thread_us > 0
    per_access = np.concatenate(
        [np.asarray(v) for v in r.transition_latencies.values()])
    # The mean must sit inside the observed per-access latency envelope
    # (the old formula overstated it ~nthreads-fold under concurrency).
    assert r.mean_access_us <= per_access.max() + 1e-9
    assert r.mean_access_us >= per_access.min() - 1e-9


# --------------------------------------------------------------------- #
# Conflict scheduler invariants.
# --------------------------------------------------------------------- #
def test_wave_schedule_conflict_free_and_ordered(rng):
    b, s, lanes = 500, 37, 4
    slots = rng.integers(0, s, b).astype(np.int32)
    sched = build_wave_schedule(slots, s, lanes=lanes)
    assert sched.acc_valid.sum() == b
    # Every access appears exactly once.
    idx = np.sort(sched.acc_index[sched.acc_valid])
    np.testing.assert_array_equal(idx, np.arange(b))
    # A wave never holds two packets of the same region: same-region
    # packets share a lane, and lanes replay in trace order.
    lane_of_acc = sched.lane_of_slot[slots]
    for g in range(lanes):
        mine = np.flatnonzero(lane_of_acc == g)
        np.testing.assert_array_equal(sched.acc_index[g, : len(mine)], mine)
    # Wave count is bounded by the hottest lane, not the batch.
    assert sched.num_waves == sched.lane_len.max()
    assert sched.num_waves < b


def test_wave_schedule_balances_hot_regions():
    # One region with half the batch: LPT must give it its own lane.
    slots = np.concatenate([np.zeros(500, np.int32),
                            np.arange(1, 101, dtype=np.int32).repeat(5)])
    sched = build_wave_schedule(slots, 101, lanes=4)
    assert sched.num_waves == 500  # the serialization floor


# --------------------------------------------------------------------- #
# Table export.
# --------------------------------------------------------------------- #
def test_page_map_dense_contiguity():
    segs = [(0, 1 << 14, 1 << 20), (1 << 14, 1 << 15, (1 << 20) + (1 << 14)),
            (1 << 15, (1 << 15) + (1 << 13), 1 << 30)]
    pm = build_page_map(segs)
    assert pm.total_pages == (1 << 15) // 4096 + 2
    # First two segments abut -> one run; third is its own run.
    assert len(pm.run_starts) == 2
    d = pm.dense_of(np.array([1 << 20, (1 << 20) + (1 << 14), 1 << 30]))
    np.testing.assert_array_equal(d, [0, 4, 8])
    assert pm.dense_of(np.array([123]))[0] == -1
    d0, npg = pm.region_dense_span(np.array([1 << 20]), np.array([1 << 15]))
    assert (d0[0], npg[0]) == (0, 8)


def test_directory_prepop_export():
    rack = DisaggregatedRack(system="mind", num_compute_blades=2,
                             threads_per_blade=2)
    rack.cp.sys_mmap(1, 1 << 16, requesting_blade=1)
    t = rack.mmu.export_dataplane_tables()
    assert t["directory_prepop"].shape[0] == t["directory"].shape[0]
    assert t["directory_prepop"].sum() == t["directory"].shape[0] > 0


# --------------------------------------------------------------------- #
# Gating: loud refusal instead of silent divergence.
# --------------------------------------------------------------------- #
def test_baseline_systems_run_batched():
    """The no-switch baselines no longer refuse ``engine="batched"`` —
    they dispatch to their own replay engines and report so."""
    for system in ("gam", "fastswap"):
        rack = DisaggregatedRack(system=system, num_compute_blades=1,
                                 threads_per_blade=2, engine="batched")
        r = rack.run(_uniform_trace(2))
        assert r.engine == "batched" and r.stats.accesses == 500


def test_batched_rejects_packed_output_overflow():
    """The one refusal left: racks whose packed kernel outputs can't
    represent the blade set (nb > 24 bit-packing bound)."""
    rack = DisaggregatedRack(system="mind", num_compute_blades=25,
                             threads_per_blade=1, engine="batched",
                             splitting_enabled=False)
    with pytest.raises(UnsupportedByBatchedEngine):
        rack.run(_uniform_trace(25))


def test_batched_capacity_eviction_parity():
    """ISSUE 2 acceptance: a trace that overflows the directory SRAM
    (regions > max_directory_entries) replays batched with coherence
    stats identical to the scalar oracle — eviction packets reproduce
    the coldest-Invalid-else-coldest LRU policy exactly."""
    trace = _uniform_trace()
    for maxdir in (8, 24):
        rs, rb = _pair("mind", trace, max_directory_entries=maxdir)
        for f in STAT_FIELDS:
            assert getattr(rs.stats, f) == getattr(rb.stats, f), (maxdir, f)
        np.testing.assert_allclose(rb.runtime_us, rs.runtime_us, rtol=1e-9)
        np.testing.assert_allclose(rb.total_thread_us, rs.total_thread_us,
                                   rtol=1e-9)


def test_batched_capacity_multi_epoch_parity():
    """Capacity evictions + Bounded-Splitting epochs together — the
    combination the seed engine refused outright — stay stat-identical,
    and chunk boundaries must not matter."""
    trace = T.ycsb_trace("zipf", num_threads=4, read_ratio=0.5,
                         accesses_per_thread=600, store_mb=4, seed=7)
    kw = dict(num_compute_blades=2, threads_per_blade=2,
              max_directory_entries=120, epoch_us=4000.0)
    rs = DisaggregatedRack(system="mind", engine="scalar",
                           splitting_enabled=True, **kw).run(trace)
    for chunk in (32768, 97):
        rb = DisaggregatedRack(
            system="mind", engine="batched", splitting_enabled=True,
            engine_options={"chunk_size": chunk}, **kw).run(trace)
        for f in STAT_FIELDS:
            assert getattr(rs.stats, f) == getattr(rb.stats, f), (chunk, f)
        assert len(rs.epoch_reports) == len(rb.epoch_reports)
        assert rs.directory_timeline == rb.directory_timeline
        np.testing.assert_allclose(rb.runtime_us, rs.runtime_us, rtol=1e-9)


def test_region_table_exports_recency():
    """export_tables/export_recency carry the LRU ranks the eviction
    policy is keyed on, aligned with the table rows."""
    rack = DisaggregatedRack(system="mind", num_compute_blades=2,
                             threads_per_blade=2)
    rack.cp.sys_mmap(1, 1 << 18, requesting_blade=0)
    d = rack.mmu.engine.directory
    t = rack.mmu.export_dataplane_tables()
    assert t["directory_recency"].shape[0] == t["directory"].shape[0]
    ranks = {tuple(map(int, r[:2])): int(rk)
             for r, rk in zip(t["directory"], t["directory_recency"])}
    assert [k for k, _ in sorted(ranks.items(), key=lambda kv: kv[1])] == \
        d.lru_keys()
    # A lookup touch moves the entry to the hottest rank.
    coldest = d.lru_keys()[0]
    d.lookup(coldest[0])
    assert d.lru_keys()[-1] == coldest


# --------------------------------------------------------------------- #
# Blade page-cache capacity evictions (ISSUE 3): the last working-set
# refusal is gone — cache-evicting traces replay batched with exact
# scalar parity via the cache-occupancy pre-pass + eviction packets.
# --------------------------------------------------------------------- #
def test_cache_overflow_refusal_is_gone():
    """A working set far beyond the blade caches replays on
    engine='batched' instead of raising UnsupportedByBatchedEngine."""
    trace = _uniform_trace()
    rack = DisaggregatedRack(system="mind", num_compute_blades=2,
                             threads_per_blade=2, engine="batched",
                             splitting_enabled=False,
                             cache_bytes_per_blade=1 << 14)
    r = rack.run(trace)
    assert r.engine == "batched"
    assert r.stats.evicted_clean + r.stats.evicted_dirty > 0


@pytest.mark.parametrize("system", ["mind", "mind-pso"])
def test_batched_cache_eviction_parity(system):
    """ISSUE 3 acceptance: per-blade working set >> blade page cache,
    mixed reads/writes so both dirty write-backs (evicted_dirty, and
    their flushed_pages share) and clean drops (evicted_clean) fire —
    stats, runtime and the latency breakdown identical to scalar."""
    trace = _zipf_trace()
    for cache_bytes in (1 << 14, 1 << 15):  # 4 and 8 pages per blade
        rs, rb = _pair(system, trace, cache_bytes_per_blade=cache_bytes)
        assert rs.stats.evicted_dirty > 0 and rs.stats.evicted_clean > 0
        for f in STAT_FIELDS:
            assert getattr(rs.stats, f) == getattr(rb.stats, f), \
                (cache_bytes, f)
        np.testing.assert_allclose(rb.runtime_us, rs.runtime_us, rtol=1e-9)
        np.testing.assert_allclose(rb.total_thread_us, rs.total_thread_us,
                                   rtol=1e-9)
        for k, v in rs.latency_breakdown_us.items():
            np.testing.assert_allclose(rb.latency_breakdown_us[k], v,
                                       rtol=1e-6, err_msg=k)


def test_batched_cache_eviction_chunk_and_lane_invariance():
    """Cache-eviction packets must land in the right lane and survive
    chunk boundaries: LRU shadow state carries across chunks and the
    covering-region lane pinning keeps any lane count exact."""
    trace = _zipf_trace()
    kw = dict(cache_bytes_per_blade=1 << 14)
    rs, _ = _pair("mind", trace, **kw)
    for opts in ({"chunk_size": 64}, {"chunk_size": 7}, {"lanes": 1},
                 {"lanes": 8}):
        rb = DisaggregatedRack(
            system="mind", num_compute_blades=2, threads_per_blade=2,
            splitting_enabled=False, engine="batched", engine_options=opts,
            **kw).run(trace)
        for f in STAT_FIELDS:
            assert getattr(rs.stats, f) == getattr(rb.stats, f), (opts, f)
        np.testing.assert_allclose(rb.runtime_us, rs.runtime_us, rtol=1e-9)


def test_batched_cache_plus_directory_capacity_multi_epoch_parity():
    """The full pressure cocktail — directory SRAM evictions, blade
    page-cache evictions and Bounded-Splitting epochs in one trace —
    stays stat-identical for any chunk size."""
    trace = T.ycsb_trace("zipf", num_threads=4, read_ratio=0.5,
                         accesses_per_thread=600, store_mb=4, seed=7)
    kw = dict(num_compute_blades=2, threads_per_blade=2,
              max_directory_entries=120, epoch_us=4000.0,
              cache_bytes_per_blade=1 << 16)
    rs = DisaggregatedRack(system="mind", engine="scalar",
                           splitting_enabled=True, **kw).run(trace)
    assert rs.stats.evicted_dirty + rs.stats.evicted_clean > 0
    for chunk in (32768, 97):
        rb = DisaggregatedRack(
            system="mind", engine="batched", splitting_enabled=True,
            engine_options={"chunk_size": chunk}, **kw).run(trace)
        for f in STAT_FIELDS:
            assert getattr(rs.stats, f) == getattr(rb.stats, f), (chunk, f)
        assert len(rs.epoch_reports) == len(rb.epoch_reports)
        assert rs.directory_timeline == rb.directory_timeline
        np.testing.assert_allclose(rb.runtime_us, rs.runtime_us, rtol=1e-9)
