"""Fault plane (ISSUE 9): blade failures, lossy fabric, invariants.

Pins the tentpole contracts:

* fault schedules are loudly validated (``ValueError`` naming the
  offending entry) and generalize the old single-shot switch kill;
* a blade kill/restore replay converges *exactly* (stats, runtime,
  breakdown) to the fault-free run on both engines — data loss is
  accounted in :class:`~repro.core.faults.FaultReport`, never simulated
  as corruption;
* the lossy fabric's retry/backoff draw is a pure function of
  ``(fabric_seed, access index)`` shared by both engines, so lossy
  replays are byte-identical scalar vs batched;
* :func:`repro.telemetry.check_invariants` passes every parity regime
  and catches a deliberately corrupted stream.
"""

from __future__ import annotations

import dataclasses

import numpy as np
import pytest

import repro.core.traces as T
from repro.core import faults as flt
from repro.core.emulator import DisaggregatedRack, ShardedRack
from repro.core.types import NetworkConstants
from repro.telemetry import (
    CoherenceInvariantError,
    Telemetry,
    canonical,
    check_invariants,
)
from repro.telemetry.events import (
    ACCESS,
    BLADE_KILL,
    BLADE_RESTORE,
    DOWNGRADE,
    INVALIDATE,
    REMAP,
    RETRY,
    TIMEOUT,
    WRITEBACK,
    Event,
)

LOSSY = dict(fabric_loss_prob=0.25, fabric_timeout_us=12.0,
             fabric_backoff=2.0, fabric_timeout_cap_us=96.0,
             fabric_max_retries=3, fabric_seed=11)

_KW = dict(num_compute_blades=2, threads_per_blade=2,
           splitting_enabled=False)


def _trace(n=250, seed=3):
    return T.tf_trace(num_threads=4, accesses_per_thread=n, seed=seed)


def _rack(engine="scalar", system="mind", sharded=False, constants=None,
          **kw):
    kw = {**_KW, **kw}
    if sharded:
        return ShardedRack(num_shards=2, system=system, engine=engine,
                           constants=constants, telemetry=Telemetry(),
                           **kw)
    return DisaggregatedRack(system=system, engine=engine,
                             constants=constants, telemetry=Telemetry(),
                             **kw)


def _assert_identical(a, b, ctx=""):
    assert a.stats == b.stats, ctx
    assert a.runtime_us == b.runtime_us, ctx
    assert a.total_thread_us == b.total_thread_us, ctx
    for key in a.latency_breakdown_us:
        np.testing.assert_allclose(
            a.latency_breakdown_us[key], b.latency_breakdown_us[key],
            rtol=1e-9, err_msg=f"{ctx} breakdown[{key}]")


def _assert_event_parity(a, b):
    ea = [e.key() for e in canonical(a.telemetry.recorder.events)]
    eb = [e.key() for e in canonical(b.telemetry.recorder.events)]
    assert ea == eb


# --------------------------------------------------------------------- #
# FabricModel: the deterministic retry/backoff draw.
# --------------------------------------------------------------------- #
def test_fabric_draw_scalar_and_vectorized_agree_bitwise():
    fab = flt.FabricModel(NetworkConstants(**LOSSY))
    n = 4096
    k_all, to_all, cost_all = fab.draw(np.arange(n))
    for i in (0, 1, 17, 999, n - 1):
        k1, to1, c1 = fab.draw(i)
        assert k1[0] == k_all[i]
        assert to1[0] == to_all[i]
        assert c1[0] == cost_all[i]  # bit-equal, not approximately


def test_fabric_draw_is_seed_dependent():
    a = flt.FabricModel(NetworkConstants(**LOSSY))
    b = flt.FabricModel(NetworkConstants(**{**LOSSY, "fabric_seed": 12}))
    _, _, ca = a.draw(np.arange(512))
    _, _, cb = b.draw(np.arange(512))
    assert (ca != cb).any()


def test_fabric_costs_follow_capped_backoff_table():
    k = NetworkConstants(**LOSSY)
    fab = flt.FabricModel(k)
    # cum[j] = sum of min(timeout * backoff^i, cap) for i < j
    delays = [min(k.fabric_timeout_us * k.fabric_backoff ** i,
                  k.fabric_timeout_cap_us)
              for i in range(k.fabric_max_retries)]
    kk, to, cost = fab.draw(np.arange(20000))
    assert int(kk.max()) <= k.fabric_max_retries
    assert to.any() and (~to).any()  # both outcomes at 25% loss
    expect = np.cumsum([0.0] + delays)[kk] \
        + np.where(to, k.fabric_timeout_cap_us, 0.0)
    np.testing.assert_array_equal(cost, expect)
    assert cost.max() <= fab.max_cost_us


def test_fabric_constants_validated():
    with pytest.raises(ValueError, match="fabric_loss_prob"):
        flt.FabricModel(NetworkConstants(**{**LOSSY,
                                            "fabric_loss_prob": 1.5}))
    with pytest.raises(ValueError, match="fabric_max_retries"):
        flt.FabricModel(NetworkConstants(**{**LOSSY,
                                            "fabric_max_retries": 0}))


@pytest.mark.parametrize("system", ["gam", "fastswap"])
def test_lossy_fabric_refused_without_switch(system):
    with pytest.raises(ValueError, match="no switch"):
        DisaggregatedRack(system=system, num_compute_blades=2,
                          threads_per_blade=2,
                          constants=NetworkConstants(**LOSSY))


# --------------------------------------------------------------------- #
# Fault-schedule validation: loud, naming the offending entry.
# --------------------------------------------------------------------- #
def test_schedule_rejects_unknown_kind():
    r = _rack()
    with pytest.raises(ValueError, match="unknown fault kind"):
        r.schedule_fault_plan([flt.FaultEvent(5, "meteor_strike", 0)])


def test_schedule_rejects_negative_index():
    r = _rack()
    with pytest.raises(ValueError, match="negative access index"):
        r.schedule_blade_kill(-3, 0)


def test_run_rejects_out_of_range_index():
    r = _rack()
    tr = _trace(n=10)  # 40 accesses
    r.schedule_blade_kill(len(tr) + 5, 0)
    with pytest.raises(ValueError, match="access index out of range"):
        r.run(tr)


def test_schedule_rejects_unknown_blade():
    r = _rack()
    with pytest.raises(ValueError, match="unknown memory blade"):
        r.schedule_blade_kill(5, 99)


def test_schedule_rejects_switch_kill_on_unsharded_rack():
    r = _rack()
    with pytest.raises(ValueError, match="sharded rack"):
        r.schedule_fault_plan([flt.FaultEvent(5, flt.SWITCH_KILL, 0)])


def test_schedule_rejects_overlapping_events():
    r = _rack()
    r.schedule_blade_kill(5, 0)
    with pytest.raises(ValueError, match="overlapping fault events"):
        r.schedule_blade_restore(5, 0)


def test_schedule_rejects_double_kill():
    r = _rack()
    r.schedule_blade_kill(5, 0)
    with pytest.raises(ValueError, match="already dead"):
        r.schedule_blade_kill(9, 0)


def test_schedule_rejects_restore_of_alive_blade():
    r = _rack()
    with pytest.raises(ValueError, match="is alive"):
        r.schedule_blade_restore(5, 0)


def test_schedule_rejects_quarantining_every_blade():
    r = _rack(num_memory_blades=2)
    r.schedule_blade_kill(5, 0)
    with pytest.raises(ValueError, match="every memory blade"):
        r.schedule_blade_kill(9, 1)


def test_schedule_rejects_faults_on_switchless_system():
    r = _rack(system="gam")
    with pytest.raises(ValueError, match="no switch"):
        r.schedule_blade_kill(5, 0)


def test_error_names_the_offending_entry():
    r = _rack()
    with pytest.raises(ValueError, match=r"blade_kill\(index=5, target=99\)"):
        r.schedule_blade_kill(5, 99)


# --------------------------------------------------------------------- #
# Blade kill/restore: exact convergence + accounted loss.
# --------------------------------------------------------------------- #
def _kill_plan(n):
    return [flt.FaultEvent(n // 4, flt.BLADE_KILL, 0),
            flt.FaultEvent(n // 2, flt.BLADE_RESTORE, 0),
            flt.FaultEvent(3 * n // 4, flt.BLADE_KILL, 1)]


@pytest.mark.parametrize("engine", ["scalar", "batched"])
@pytest.mark.parametrize("durable", [False, True])
def test_blade_kill_replay_converges_exactly(engine, durable):
    tr = _trace()
    base = _rack(engine).run(tr)
    r = _rack(engine, durable_writebacks=durable)
    r.schedule_fault_plan(_kill_plan(len(tr)))
    faulted = r.run(tr)
    _assert_identical(base, faulted, f"{engine}/durable={durable}")
    assert [f.kind for f in faulted.fault_reports] == \
        [flt.BLADE_KILL, flt.BLADE_RESTORE, flt.BLADE_KILL]


def test_blade_kill_fault_reports_match_across_engines():
    tr = _trace()
    res = {}
    for engine in ("scalar", "batched"):
        r = _rack(engine)
        r.schedule_fault_plan(_kill_plan(len(tr)))
        res[engine] = r.run(tr)
    _assert_identical(res["scalar"], res["batched"], "kill parity")
    _assert_event_parity(res["scalar"], res["batched"])
    assert res["scalar"].fault_reports == res["batched"].fault_reports


def _blade_written_before(res, rack, upto):
    """written-region counts per memory blade from the ACCESS stream."""
    spans = {b: (s.va_base, s.va_end)
             for b, s in rack.mmu.gas.blades.items()}
    counts = dict.fromkeys(spans, 0)
    for e in res.telemetry.recorder.events:
        if e.kind == ACCESS and e.write == 1 and 0 <= e.index < upto:
            for b, (lo, hi) in spans.items():
                if lo <= e.base < hi:
                    counts[b] += 1
                    break
    return counts


@pytest.mark.parametrize("durable", [False, True])
def test_blade_kill_accounts_dirty_pages(durable):
    """Kill the most-written blade mid-trace: written pages classify
    exhaustively into preserved / lost-or-refetched, and durable
    write-backs turn every loss into a refetch."""
    tr = _trace()
    probe = _rack()
    res = probe.run(tr)
    kill_at = len(tr) // 2
    counts = _blade_written_before(res, probe, kill_at)
    blade = max(counts, key=counts.get)
    assert counts[blade] > 0, "trace writes nothing? pick another seed"

    r = _rack(durable_writebacks=durable)
    r.schedule_blade_kill(kill_at, blade)
    rep = r.run(tr).fault_reports[0]
    assert rep.pages_written > 0
    assert rep.pages_written == (rep.pages_dirty_preserved
                                 + rep.pages_dirty_lost
                                 + rep.pages_dirty_refetched)
    if durable:
        assert rep.pages_dirty_lost == 0
    else:
        assert rep.pages_dirty_refetched == 0
    assert rep.vmas_remapped > 0 and rep.bytes_remapped > 0


def test_back_to_back_kill_restore_cycles():
    """The satellite pin: the generalized schedule handles tight
    repeated cycles the old single-shot ``_kill_at`` could not."""
    tr = _trace()
    plan = []
    for c, i in enumerate(range(100, 112, 2)):
        plan += [flt.FaultEvent(i, flt.BLADE_KILL, c % 2),
                 flt.FaultEvent(i + 1, flt.BLADE_RESTORE, c % 2)]
    res = {}
    for engine in ("scalar", "batched"):
        r = _rack(engine)
        r.schedule_fault_plan(plan)
        res[engine] = r.run(tr)
        assert len(res[engine].fault_reports) == len(plan)
    base = _rack().run(tr)
    _assert_identical(base, res["scalar"], "cycles converge")
    _assert_identical(res["scalar"], res["batched"], "cycles parity")
    assert res["scalar"].fault_reports == res["batched"].fault_reports


def test_blade_fault_events_reach_the_recorder():
    tr = _trace()
    r = _rack()
    r.schedule_fault_plan(_kill_plan(len(tr)))
    res = r.run(tr)
    kinds = [e.kind for e in res.telemetry.recorder.events
             if e.kind in (BLADE_KILL, BLADE_RESTORE, REMAP)]
    assert kinds.count(BLADE_KILL) == 2
    assert kinds.count(BLADE_RESTORE) == 1
    assert kinds.count(REMAP) == sum(
        f.vmas_remapped for f in res.fault_reports)
    m = res.telemetry.metrics
    assert m.total("blade_kills_total") == 2
    assert m.total("blade_restores_total") == 1
    assert m.total("remapped_vmas_total") == kinds.count(REMAP)


def test_killed_blade_excluded_from_placement():
    r = _rack()
    r.allocator.dead.add(0)
    from repro.core.types import Perm
    vma = r.cp.sys_mmap(2, 1 << 20, Perm.RW, requesting_blade=0).vma
    assert vma.blade_id != 0


# --------------------------------------------------------------------- #
# Lossy fabric: byte-identical scalar vs batched replays.
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("system", ["mind", "mind-pso", "mind-pso+"])
def test_lossy_fabric_parity(system):
    tr = _trace()
    k = NetworkConstants(**LOSSY)
    rs = _rack("scalar", system=system, constants=k).run(tr)
    rb = _rack("batched", system=system, constants=k).run(tr)
    _assert_identical(rs, rb, system)
    _assert_event_parity(rs, rb)
    assert rs.latency_breakdown_us["retry"] > 0.0


def test_lossy_fabric_parity_under_directory_pressure():
    tr = _trace()
    k = NetworkConstants(**LOSSY)
    rs = _rack("scalar", constants=k, max_directory_entries=120).run(tr)
    rb = _rack("batched", constants=k, max_directory_entries=120).run(tr)
    _assert_identical(rs, rb, "dir pressure")
    _assert_event_parity(rs, rb)
    tos = [e for e in rs.telemetry.recorder.events if e.kind == TIMEOUT]
    assert tos, "25% loss over a chatty trace should exhaust a budget"


def test_lossy_fabric_parity_on_sharded_rack():
    tr = T.sharded_conflict_trace(num_threads=4, accesses_per_thread=250,
                                  num_shards=4, blocks_per_shard=2, seed=9)
    k = NetworkConstants(**LOSSY)
    rs = _rack("scalar", sharded=True, constants=k).run(tr)
    rb = _rack("batched", sharded=True, constants=k).run(tr)
    _assert_identical(rs, rb, "sharded lossy")
    _assert_event_parity(rs, rb)


def test_retry_events_match_breakdown_charge():
    tr = _trace()
    res = _rack(constants=NetworkConstants(**LOSSY)).run(tr)
    evs = [e for e in res.telemetry.recorder.events
           if e.kind in (RETRY, TIMEOUT)]
    assert evs
    np.testing.assert_allclose(sum(e.us for e in evs),
                               res.latency_breakdown_us["retry"],
                               rtol=1e-9)
    m = res.telemetry.metrics
    assert m.total("fabric_retries_total") == sum(e.pages for e in evs)
    assert m.total("fabric_timeouts_total") == sum(
        1 for e in evs if e.kind == TIMEOUT)


def test_pure_local_hits_never_pay_the_fabric():
    """A single-thread run on one region: after the first fetch, every
    access is a pure local hit and the retry charge stays flat."""
    tr = T.uniform_trace(num_threads=1, read_ratio=1.0, sharing_ratio=1.0,
                         accesses_per_thread=200, working_set_pages=8,
                         seed=5)
    res = _rack(num_compute_blades=1, threads_per_blade=1,
                constants=NetworkConstants(**LOSSY)).run(tr)
    nret = sum(1 for e in res.telemetry.recorder.events
               if e.kind in (RETRY, TIMEOUT))
    # Only the non-hit prefix (cold fetches) can draw retransmissions.
    assert nret <= res.stats.remote_fetches


def test_lossless_fabric_charges_nothing():
    tr = _trace()
    base = _rack().run(tr)
    res = _rack(constants=NetworkConstants()).run(tr)
    assert res.latency_breakdown_us["retry"] == 0.0
    assert res.runtime_us == base.runtime_us


# --------------------------------------------------------------------- #
# Chaos: faults + lossy fabric together, both engines.
# --------------------------------------------------------------------- #
def test_chaos_faults_and_fabric_together():
    tr = _trace()
    k = NetworkConstants(**LOSSY)
    res = {}
    for engine in ("scalar", "batched"):
        r = _rack(engine, constants=k)
        r.schedule_fault_plan(_kill_plan(len(tr)))
        res[engine] = r.run(tr)
    _assert_identical(res["scalar"], res["batched"], "chaos")
    _assert_event_parity(res["scalar"], res["batched"])
    assert res["scalar"].fault_reports == res["batched"].fault_reports
    assert check_invariants(res["scalar"].telemetry) == []
    assert check_invariants(res["batched"].telemetry) == []


# --------------------------------------------------------------------- #
# Coherence invariant checker.
# --------------------------------------------------------------------- #
_REGIMES = {
    "plain": dict(),
    "pso": dict(system="mind-pso"),
    "dir_pressure": dict(max_directory_entries=120),
    "cache_pressure": dict(cache_bytes_per_blade=1 << 14),
    "epochs": dict(splitting_enabled=True, epoch_us=4000.0),
    "sharded": dict(sharded=True),
}


@pytest.mark.parametrize("regime", sorted(_REGIMES))
@pytest.mark.parametrize("engine", ["scalar", "batched"])
def test_invariants_clean_on_parity_regimes(regime, engine):
    kw = dict(_REGIMES[regime])
    sharded = kw.pop("sharded", False)
    tr = (T.sharded_conflict_trace(num_threads=4, accesses_per_thread=250,
                                   num_shards=4, blocks_per_shard=2,
                                   seed=9)
          if sharded else _trace())
    res = _rack(engine, sharded=sharded, **kw).run(tr)
    assert check_invariants(res.telemetry) == []


def test_invariants_catch_corrupted_stream():
    """The pinned negative test: flip one transition kind in a real
    stream and the checker names the exact index and rule."""
    tr = T.uniform_trace(num_threads=4, read_ratio=0.5, sharing_ratio=0.8,
                         accesses_per_thread=250, working_set_pages=64,
                         seed=5)
    res = _rack().run(tr)
    evs = list(res.telemetry.recorder.events)
    post = {}  # base -> shadow state after its last access
    for i, e in enumerate(evs):
        if e.kind != ACCESS or not e.tkind or "->" not in e.tkind:
            continue
        known = post.get(e.base)
        if known in ("M", "S"):  # shadow state is pinned: contradict it
            lie = "S" if known == "M" else "M"
            evs[i] = dataclasses.replace(e, tkind=f"{lie}->{lie}")
            break
        post[e.base] = e.tkind.split("->")[1]
    else:
        pytest.fail("no revisited region to corrupt")
    v = check_invariants(evs)
    assert v and v[0].rule == "state-machine"
    assert v[0].index == evs[i].index
    with pytest.raises(CoherenceInvariantError, match="state-machine"):
        check_invariants(evs, strict=True)


def test_invariants_hit_from_invalid():
    v = check_invariants([
        Event(ACCESS, 0, blade=0, base=0, log2=14, write=0, hit=1,
              tkind="I->S"),
    ])
    assert [x.rule for x in v] == ["hit-from-invalid"]


def test_invariants_residency_and_swmr():
    v = check_invariants([
        Event(ACCESS, 0, blade=0, base=0, log2=14, write=1, hit=0,
              tkind="I->M"),
        # blade 1 "hits" a region blade 0 owns, with no invalidation.
        Event(ACCESS, 1, blade=1, base=0, log2=14, write=0, hit=1,
              tkind="M->S"),
    ])
    assert sorted(x.rule for x in v) == ["residency", "swmr"]


def test_invariants_ownership_transfer_with_invalidate_is_clean():
    v = check_invariants([
        Event(ACCESS, 0, blade=0, base=0, log2=14, write=1, hit=0,
              tkind="I->M"),
        Event(INVALIDATE, 1, blade=1, base=0, log2=14, targets=0b1,
              pages=1, flushed=0),
        Event(ACCESS, 1, blade=1, base=0, log2=14, write=1, hit=0,
              tkind="M->M"),
    ])
    assert v == []


def test_invariants_lost_writeback():
    stream = [
        Event(ACCESS, 0, blade=0, base=0, log2=14, write=1, hit=0,
              tkind="I->M"),
        Event(INVALIDATE, 1, blade=1, base=0, log2=14, targets=0b1,
              pages=4, flushed=4),
        Event(ACCESS, 1, blade=1, base=0, log2=14, write=1, hit=0,
              tkind="M->M"),
    ]
    v = check_invariants(stream)
    assert [x.rule for x in v] == ["lost-writeback"]
    stream.append(Event(WRITEBACK, 1, base=0, log2=14, pages=4))
    assert check_invariants(stream) == []


def test_invariants_downgrade_keeps_the_old_copy():
    v = check_invariants([
        Event(ACCESS, 0, blade=0, base=0, log2=14, write=1, hit=0,
              tkind="I->M"),
        Event(DOWNGRADE, 1, blade=1, base=0, log2=14, targets=0b1),
        Event(ACCESS, 1, blade=1, base=0, log2=14, write=0, hit=0,
              tkind="M->S"),
        # blade 0 kept an S copy through the downgrade: hitting is legal.
        Event(ACCESS, 2, blade=0, base=0, log2=14, write=0, hit=1,
              tkind="S->S"),
    ])
    assert v == []


def test_invariants_fault_sequencing():
    v = check_invariants([
        Event(BLADE_RESTORE, 3, blade=0),
        Event(REMAP, 7, blade=1, targets=5, base=0, log2=14, pages=4),
    ])
    assert sorted(x.rule for x in v) == ["fault-sequence",
                                        "fault-sequence"]
    clean = check_invariants([
        Event(REMAP, 3, blade=1, targets=0, base=0, log2=14, pages=4),
        Event(BLADE_KILL, 3, blade=0, targets=2),
        Event(BLADE_RESTORE, 9, blade=0),
    ])
    assert clean == []
