"""Pluggable fit policies (ISSUE 10): round-trip invariants for every
policy, snapshot/restore re-carving, find_vma index, helper edge cases,
and churn-trace determinism."""

import json

import pytest

try:  # property tests need hypothesis (CI dev extra); the rest run bare
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised on bare local installs
    HAVE_HYPOTHESIS = False

from repro.core.address_space import GlobalAddressSpace
from repro.core.alloc_policies import (
    DEFAULT_POLICY, POLICIES, ceil_log2, make_policy)
from repro.core.allocator import BladeAllocator, MemoryAllocator
from repro.core.control_plane import ControlPlane
from repro.core.switch import make_mmu
from repro.core.traces import (
    CHURN_PROFILES, MMAP, MUNMAP, alloc_churn_trace)
from repro.core.types import PAGE_SIZE, Perm, align_up, next_pow2

ALL_POLICIES = sorted(POLICIES)
VA_BASE = 1 << 36
CAPACITY = 1 << 26  # 64 MB — small enough that churn hits fragmentation


def check_policy_books(policy, live):
    """The invariants every fit policy must keep after every operation."""
    blocks = policy.free_blocks()
    # Sorted, coalesce-maximal (first_fit/buddy) or at least non-adjacent
    # within a class isn't required — but non-overlapping and in-range is.
    for (b0, l0), (b1, l1) in zip(blocks, blocks[1:]):
        assert b0 + l0 <= b1, f"free blocks overlap/unsorted: {blocks}"
    for b, l in blocks:
        assert l > 0
        assert VA_BASE <= b and b + l <= VA_BASE + CAPACITY
    # Conservation: free + reserved == capacity, reserved covers live.
    assert policy.free_bytes + policy.reserved_bytes == CAPACITY
    assert policy.reserved_bytes >= sum(l for _, l in live)
    assert policy.largest_free == max((l for _, l in blocks), default=0)
    # Live allocations never overlap each other or any free block.
    spans = sorted(live) + blocks
    spans.sort()
    for (b0, l0), (b1, l1) in zip(spans, spans[1:]):
        assert b0 + l0 <= b1, f"overlap between live+free spans: {spans}"


def _roundtrip(name, ops):
    """Interleaved alloc/free against one policy: conservation, sorted
    non-overlapping free blocks, alignment honored, full capacity back
    after draining."""
    policy = make_policy(name, VA_BASE, CAPACITY)
    live = []  # (base, length) pairs as the policy saw them
    for op, size in ops:
        if op == "alloc" or not live:
            length = next_pow2(align_up(size, PAGE_SIZE))
            base = policy.alloc(length, length)
            if base is None:
                continue
            assert base % length == 0, f"{name}: base not size-aligned"
            live.append((base, length))
        else:
            base, length = live.pop(len(live) // 2)
            policy.free_range(base, length)
        check_policy_books(policy, live)
    for base, length in live:
        policy.free_range(base, length)
    check_policy_books(policy, [])
    assert policy.reserved_bytes == 0
    assert policy.free_bytes == CAPACITY


@pytest.mark.parametrize("name", ALL_POLICIES)
def test_policy_roundtrip_smoke(name):
    """Deterministic round-trip (runs even without hypothesis)."""
    ops = [("alloc", (1 << (12 + i % 9)) - (i % 3)) for i in range(30)]
    ops += [("free", 1), ("alloc", 5000), ("free", 1), ("free", 1),
            ("alloc", 3 << 20), ("free", 1), ("alloc", 1)] * 4
    _roundtrip(name, ops)


if HAVE_HYPOTHESIS:
    @pytest.mark.parametrize("name", ALL_POLICIES)
    @given(ops=st.lists(
        st.tuples(st.sampled_from(["alloc", "free"]),
                  st.integers(min_value=1, max_value=1 << 22)),
        min_size=1, max_size=80))
    @settings(max_examples=40, deadline=None)
    def test_policy_roundtrip_invariants(name, ops):
        _roundtrip(name, ops)


@pytest.mark.parametrize("name", ALL_POLICIES)
def test_policy_state_roundtrip(name):
    """export_state/load_state reproduces byte-identical follow-on
    decisions (the §3.2 failover contract at the policy layer)."""
    a = make_policy(name, VA_BASE, CAPACITY)
    bases = [a.alloc(1 << (12 + i % 5), 1 << (12 + i % 5)) for i in range(40)]
    for b, i in zip(bases[::3], range(0, 40, 3)):
        a.free_range(b, 1 << (12 + i % 5))
    b = make_policy(name, VA_BASE, CAPACITY)
    b.load_state(a.export_state())
    assert b.free_blocks() == a.free_blocks()
    assert b.free_bytes == a.free_bytes
    assert b.reserved_bytes == a.reserved_bytes
    for length in (1 << 12, 1 << 14, 1 << 16, 1 << 13):
        assert a.alloc(length, length) == b.alloc(length, length)


@pytest.mark.parametrize("name", ALL_POLICIES)
def test_control_plane_snapshot_restore_per_policy(name):
    """Snapshot -> restore under each policy: identical books and an
    identical next placement decision."""
    mmu, alloc = make_mmu(num_memory_blades=3, num_compute_blades=2,
                          cache_bytes_per_blade=1 << 20, alloc_policy=name,
                          blade_capacity=1 << 28)
    cp = ControlPlane(mmu, alloc)
    vmas = [cp.sys_mmap(1 + i % 3, (i % 7 + 1) * 3 * PAGE_SIZE,
                        requesting_blade=i % 2).vma for i in range(30)]
    for v in vmas[::4]:
        assert cp.sys_munmap(v.pdid, v.base).retval == 0
    snap = cp.snapshot()
    cp2 = ControlPlane.restore(snap, cache_bytes_per_blade=1 << 20,
                               num_compute_blades=2)
    assert cp2.allocator.policy_name == name
    assert cp2.allocator.allocation_by_blade() == alloc.allocation_by_blade()
    assert cp2.allocator.free_bytes_by_blade() == alloc.free_bytes_by_blade()
    for b, a in alloc.blades.items():
        assert cp2.allocator.blades[b].free_blocks() == a.free_blocks()
    v1 = cp.sys_mmap(2, 100_000).vma
    v2 = cp2.sys_mmap(2, 100_000).vma
    assert (v1.base, v1.blade_id, v1.length) == (v2.base, v2.blade_id, v2.length)


def test_default_policy_snapshot_format_unchanged():
    """The default first-fit snapshot must not grow an ``alloc`` section:
    pre-PR snapshots restore, and restore-time re-carving covers it."""
    mmu, alloc = make_mmu(num_memory_blades=2, num_compute_blades=1,
                          cache_bytes_per_blade=1 << 20)
    cp = ControlPlane(mmu, alloc)
    cp.sys_mmap(1, PAGE_SIZE)
    assert DEFAULT_POLICY == "first_fit"
    assert "alloc" not in json.loads(cp.snapshot())
    mmu2, alloc2 = make_mmu(num_memory_blades=2, num_compute_blades=1,
                            cache_bytes_per_blade=1 << 20,
                            alloc_policy="buddy")
    cp2 = ControlPlane(mmu2, alloc2)
    cp2.sys_mmap(1, PAGE_SIZE)
    assert json.loads(cp2.snapshot())["alloc"]["policy"] == "buddy"


def test_unknown_policy_rejected():
    with pytest.raises(ValueError, match="unknown fit policy 'best_fit'"):
        make_policy("best_fit", VA_BASE, CAPACITY)


def test_rack_policy_plumbing():
    """alloc_policy threads through make_mmu down to every blade."""
    mmu, alloc = make_mmu(num_memory_blades=2, num_compute_blades=1,
                          cache_bytes_per_blade=1 << 20,
                          alloc_policy="segregated")
    assert alloc.policy_name == "segregated"
    for b in alloc.blades.values():
        assert type(b.policy).__name__ == "SegregatedPolicy"


@pytest.mark.parametrize("name", ALL_POLICIES)
def test_churn_replay_drains_clean(name):
    """A full churn trace replays and drains against every policy with
    conservation intact (the alloc_bench contract, in miniature)."""
    gas = GlobalAddressSpace()
    for _ in range(2):
        gas.add_blade(1 << 28)
    alloc = MemoryAllocator(gas, policy=name)
    trace = alloc_churn_trace(profile="mixed", num_events=400, seed=3)
    base_of = {}
    for i, kind, pdid, arg in trace.events():
        if kind == MMAP:
            try:
                base_of[i] = alloc.mmap(pdid, arg).base
            except MemoryError:
                base_of[i] = None
        else:
            base = base_of.pop(arg)
            if base is not None:
                alloc.munmap(base)
    for base in [b for b in base_of.values() if b is not None]:
        alloc.munmap(base)
    for b in alloc.blades.values():
        b.check_conservation()
    assert sum(alloc.allocation_by_blade().values()) == 0


# --------------------------------------------------------------------- #
# find_vma bisect index vs the seed's O(n) scan (satellite 4).

def _gas(blades):
    gas = GlobalAddressSpace()
    for _ in range(blades):
        gas.add_blade()
    return gas


def _bisect_vs_scan(ops, probes):
    a = MemoryAllocator(_gas(2))
    live = []
    for op, size in ops:
        if op == "alloc" or not live:
            try:
                live.append(a.mmap(1, size))
            except MemoryError:
                continue
        else:
            a.munmap(live.pop(0).base)
        addrs = [0, 1 << 62]
        for v in live:
            for d in probes:
                addrs += [v.base + d, v.end + d, v.base + v.length // 2]
        for addr in addrs:
            assert a.find_vma(addr) is a._find_vma_scan(addr)


def test_find_vma_bisect_matches_scan_smoke():
    ops = [("alloc", 1 << (12 + i % 6)) for i in range(20)]
    ops += [("free", 1), ("alloc", 7777), ("free", 1)] * 5
    _bisect_vs_scan(ops, probes=[-2, -1, 0, 1, 2])


if HAVE_HYPOTHESIS:
    @given(ops=st.lists(
        st.tuples(st.sampled_from(["alloc", "free"]),
                  st.integers(min_value=1, max_value=1 << 20)),
        min_size=1, max_size=50),
        probes=st.lists(st.integers(min_value=-2, max_value=2), min_size=1,
                        max_size=8))
    @settings(max_examples=40, deadline=None)
    def test_find_vma_bisect_matches_scan(ops, probes):
        _bisect_vs_scan(ops, probes)


# --------------------------------------------------------------------- #
# Helper edge cases (satellite 3).

@pytest.mark.parametrize("x,want", [
    (0, 1), (1, 1), (2, 2), (3, 4), (4, 4), (5, 8),
    (4095, 4096), (4096, 4096), (4097, 8192),
    ((1 << 30) - 1, 1 << 30), ((1 << 30) + 1, 1 << 31),
])
def test_next_pow2_edges(x, want):
    assert next_pow2(x) == want


@pytest.mark.parametrize("x,a,want", [
    (0, 4096, 0), (1, 4096, 4096), (4096, 4096, 4096),
    (4097, 4096, 8192), (1, 1, 1), (12345, 8, 12352),
])
def test_align_up_edges(x, a, want):
    assert align_up(x, a) == want


@pytest.mark.parametrize("x,want", [
    (1, 0), (2, 1), (3, 2), (4, 2), (5, 3), (4096, 12), (4097, 13),
])
def test_ceil_log2_edges(x, want):
    assert ceil_log2(x) == want


# --------------------------------------------------------------------- #
# Churn trace generator (satellite/tentpole workload).

def test_churn_trace_deterministic():
    t1 = alloc_churn_trace(profile="small", num_events=300, seed=7)
    t2 = alloc_churn_trace(profile="small", num_events=300, seed=7)
    assert (t1.kinds == t2.kinds).all()
    assert (t1.pdids == t2.pdids).all()
    assert (t1.args == t2.args).all()
    t3 = alloc_churn_trace(profile="small", num_events=300, seed=8)
    assert not (t3.args == t1.args).all()


@pytest.mark.parametrize("profile", sorted(CHURN_PROFILES))
def test_churn_trace_well_formed(profile):
    """Every munmap references an earlier, not-yet-freed mmap event of
    the same trace; sizes match the profile's classes."""
    t = alloc_churn_trace(profile=profile, num_events=500)
    assert len(t) == 500
    live = set()
    max_cls = 1 << max(CHURN_PROFILES[profile]["class_log2s"])
    for i, kind, pdid, arg in t.events():
        assert 1 <= pdid <= t.num_pdids
        if kind == MMAP:
            assert 0 < arg <= max_cls
            live.add(i)
        else:
            assert kind == MUNMAP
            assert arg in live, "munmap of unknown/freed event"
            live.remove(arg)
    frees = int((t.kinds == MUNMAP).sum())
    assert frees > len(t) // 5, "profile should be free-heavy churn"
