"""Allocator: balanced placement, first-fit, fragmentation, fairness,
and the ISSUE 10 hardening pins (validated frees, mmap/munmap errors)."""

import pytest

try:  # property tests need hypothesis (CI dev extra); the rest run bare
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised on bare local installs
    HAVE_HYPOTHESIS = False

from repro.core.address_space import GlobalAddressSpace
from repro.core.allocator import MemoryAllocator
from repro.core.types import PAGE_SIZE, Perm


def make_alloc(blades=4, pow2=True):
    gas = GlobalAddressSpace()
    for _ in range(blades):
        gas.add_blade()
    return MemoryAllocator(gas, pow2_align=pow2)


def test_least_allocated_placement():
    a = make_alloc(4)
    vmas = [a.mmap(1, 1 << 20) for _ in range(8)]
    by_blade = a.allocation_by_blade()
    # 8 equal allocations over 4 blades -> 2 each (§4.1 load balancing).
    assert set(by_blade.values()) == {2 << 20}
    assert a.jain_fairness() == pytest.approx(1.0)


def test_pow2_rounding_and_alignment():
    a = make_alloc(1)
    vma = a.mmap(1, 5000)
    assert vma.length == 8192
    assert vma.base % 8192 == 0


def test_first_fit_reuses_freed_range():
    a = make_alloc(1)
    v1 = a.mmap(1, 64 * PAGE_SIZE)
    v2 = a.mmap(1, 64 * PAGE_SIZE)
    a.munmap(v1.base)
    v3 = a.mmap(1, 64 * PAGE_SIZE)
    assert v3.base == v1.base  # address-ordered first fit


def test_oom_raises():
    a = make_alloc(1)
    cap = a.blades[0].capacity
    a.mmap(1, cap)
    with pytest.raises(MemoryError):
        a.mmap(1, PAGE_SIZE)


def test_find_vma():
    a = make_alloc(2)
    v = a.mmap(7, 4 * PAGE_SIZE, Perm.READ)
    assert a.find_vma(v.base + 100).pdid == 7
    assert a.find_vma(v.base - 1) is None


# --------------------------------------------------------------------- #
# Hardening pins (ISSUE 10 satellites 1-3).  Each of these silently
# corrupted accounting or raised an anonymous KeyError pre-PR; the match
# strings pin the named errors so regressions change a message, not a
# behaviour.

def test_double_free_rejected():
    a = make_alloc(1)
    v = a.mmap(1, PAGE_SIZE)
    blade = a.blades[v.blade_id]
    blade.free_range(v.base, v.length)
    with pytest.raises(ValueError,
                       match="no live allocation at this base"):
        blade.free_range(v.base, v.length)


def test_overlapping_free_rejected():
    """Freeing from inside a live vma (not at its base) must not split
    the accounting — pre-PR this grew the free list past capacity."""
    a = make_alloc(1)
    v = a.mmap(1, 4 * PAGE_SIZE)
    with pytest.raises(ValueError,
                       match="double free or overlapping free"):
        a.blades[v.blade_id].free_range(v.base + PAGE_SIZE, PAGE_SIZE)


def test_free_length_mismatch_rejected():
    a = make_alloc(1)
    v = a.mmap(1, 4 * PAGE_SIZE)
    with pytest.raises(ValueError,
                       match="does not match the allocated"):
        a.blades[v.blade_id].free_range(v.base, PAGE_SIZE)


def test_out_of_range_free_rejected():
    a = make_alloc(1)
    blade = a.blades[0]
    with pytest.raises(ValueError, match="outside blade range"):
        blade.free_range(blade.va_base - PAGE_SIZE, PAGE_SIZE)
    with pytest.raises(ValueError, match="outside blade range"):
        blade.free_range(blade.va_base + blade.capacity - PAGE_SIZE,
                         2 * PAGE_SIZE)


def test_mmap_rejects_nonpositive_length():
    """mmap(0) used to mint a 1-byte vma via next_pow2(0) == 1."""
    a = make_alloc(1)
    with pytest.raises(ValueError, match="mmap length must be positive"):
        a.mmap(1, 0)
    with pytest.raises(ValueError, match="mmap length must be positive"):
        a.mmap(1, -4096)
    assert not a.vmas  # nothing leaked into the vma table


def test_munmap_unknown_base_named_error():
    """Pre-PR: bare KeyError from the vmas dict."""
    a = make_alloc(1)
    with pytest.raises(ValueError,
                       match="munmap of unknown base 0xdead"):
        a.munmap(0xdead)


def test_munmap_after_blade_retired_is_counted_not_crash():
    """A vma whose VA range died with a retired blade: the free has no
    free-structure to return to — explicit accounting, not a KeyError."""
    a = make_alloc(2)
    v = a.mmap(1, PAGE_SIZE)
    a.on_blade_retired(v.blade_id)
    a.munmap(v.base)  # pre-PR: KeyError on the popped blade
    assert a.orphaned_frees == 1
    assert a.find_vma(v.base) is None
    # The survivor's books still balance.
    for b in a.blades.values():
        b.check_conservation()


if HAVE_HYPOTHESIS:
    @given(
        st.lists(
            st.tuples(st.sampled_from(["alloc", "free"]),
                      st.integers(min_value=1, max_value=1 << 22)),
            min_size=1, max_size=60,
        )
    )
    @settings(max_examples=50, deadline=None)
    def test_alloc_free_invariants(ops):
        """No overlapping vmas; accounting consistent; free returns capacity."""
        a = make_alloc(2)
        live = []
        for op, size in ops:
            if op == "alloc" or not live:
                try:
                    v = a.mmap(1, size)
                    live.append(v)
                except MemoryError:
                    continue
            else:
                v = live.pop()
                a.munmap(v.base)
            # no overlaps among live vmas
            spans = sorted((v.base, v.end) for v in live)
            for (s0, e0), (s1, e1) in zip(spans, spans[1:]):
                assert e0 <= s1
            # accounting
            assert sum(a.allocation_by_blade().values()) == sum(
                v.length for v in live
            )
        for v in live:
            a.munmap(v.base)
        assert sum(a.allocation_by_blade().values()) == 0
        # capacity fully restored
        for b in a.blades.values():
            assert b.largest_free == b.capacity
