"""Allocator: balanced placement, first-fit, fragmentation, fairness."""

import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.address_space import GlobalAddressSpace
from repro.core.allocator import MemoryAllocator
from repro.core.types import PAGE_SIZE, Perm


def make_alloc(blades=4, pow2=True):
    gas = GlobalAddressSpace()
    for _ in range(blades):
        gas.add_blade()
    return MemoryAllocator(gas, pow2_align=pow2)


def test_least_allocated_placement():
    a = make_alloc(4)
    vmas = [a.mmap(1, 1 << 20) for _ in range(8)]
    by_blade = a.allocation_by_blade()
    # 8 equal allocations over 4 blades -> 2 each (§4.1 load balancing).
    assert set(by_blade.values()) == {2 << 20}
    assert a.jain_fairness() == pytest.approx(1.0)


def test_pow2_rounding_and_alignment():
    a = make_alloc(1)
    vma = a.mmap(1, 5000)
    assert vma.length == 8192
    assert vma.base % 8192 == 0


def test_first_fit_reuses_freed_range():
    a = make_alloc(1)
    v1 = a.mmap(1, 64 * PAGE_SIZE)
    v2 = a.mmap(1, 64 * PAGE_SIZE)
    a.munmap(v1.base)
    v3 = a.mmap(1, 64 * PAGE_SIZE)
    assert v3.base == v1.base  # address-ordered first fit


def test_oom_raises():
    a = make_alloc(1)
    cap = a.blades[0].capacity
    a.mmap(1, cap)
    with pytest.raises(MemoryError):
        a.mmap(1, PAGE_SIZE)


def test_find_vma():
    a = make_alloc(2)
    v = a.mmap(7, 4 * PAGE_SIZE, Perm.READ)
    assert a.find_vma(v.base + 100).pdid == 7
    assert a.find_vma(v.base - 1) is None


@given(
    st.lists(
        st.tuples(st.sampled_from(["alloc", "free"]),
                  st.integers(min_value=1, max_value=1 << 22)),
        min_size=1, max_size=60,
    )
)
@settings(max_examples=50, deadline=None)
def test_alloc_free_invariants(ops):
    """No overlapping vmas; accounting consistent; free returns capacity."""
    a = make_alloc(2)
    live = []
    for op, size in ops:
        if op == "alloc" or not live:
            try:
                v = a.mmap(1, size)
                live.append(v)
            except MemoryError:
                continue
        else:
            v = live.pop()
            a.munmap(v.base)
        # no overlaps among live vmas
        spans = sorted((v.base, v.end) for v in live)
        for (s0, e0), (s1, e1) in zip(spans, spans[1:]):
            assert e0 <= s1
        # accounting
        assert sum(a.allocation_by_blade().values()) == sum(
            v.length for v in live
        )
    for v in live:
        a.munmap(v.base)
    assert sum(a.allocation_by_blade().values()) == 0
    # capacity fully restored
    for b in a.blades.values():
        assert b.largest_free == b.capacity
