"""Training loop + checkpointing: loss goes down, microbatch equivalence,
restart determinism (interrupted == uninterrupted)."""

import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import get_config, reduced_config
from repro.data.pipeline import DataConfig, ShardedLoader
from repro.models.model import LM
from repro.optim import adamw
from repro.optim.adamw import AdamWConfig
from repro.training.train_loop import make_train_step


def setup(arch="gemma-2b", batch=4, seq=32, steps=10, micro=1):
    cfg = dataclasses.replace(reduced_config(get_config(arch)),
                              vocab_size=512)
    model = LM(cfg, remat=True)
    opt_cfg = AdamWConfig(lr=1e-3, total_steps=steps, warmup_steps=2)
    step_fn = jax.jit(make_train_step(model, opt_cfg, microbatches=micro))
    loader = ShardedLoader(
        DataConfig(vocab_size=cfg.vocab_size, seq_len=seq, global_batch=batch),
        cfg)
    params = model.init(jax.random.key(0))
    opt = adamw.init(params)
    return model, step_fn, loader, params, opt


def test_loss_decreases_over_30_steps():
    model, step_fn, loader, params, opt = setup(steps=30)
    losses = []
    for s in range(30):
        batch = {k: jnp.asarray(v) for k, v in loader.batch(s).items()}
        params, opt, m = step_fn(params, opt, batch)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.05


def test_microbatch_equals_full_batch_grads():
    """Grad accumulation must match the monolithic step numerically."""
    cfg = dataclasses.replace(reduced_config(get_config("qwen3-4b")),
                              vocab_size=256, compute_dtype="float32")
    model = LM(cfg)
    opt_cfg = AdamWConfig(lr=1e-3, total_steps=5)
    loader = ShardedLoader(
        DataConfig(vocab_size=256, seq_len=16, global_batch=4), cfg)
    params = model.init(jax.random.key(0))
    batch = {k: jnp.asarray(v) for k, v in loader.batch(0).items()}

    s1 = make_train_step(model, opt_cfg, microbatches=1)
    s2 = make_train_step(model, opt_cfg, microbatches=2)
    p1, _, m1 = jax.jit(s1)(params, adamw.init(params), batch)
    p2, _, m2 = jax.jit(s2)(params, adamw.init(params), batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=5e-4, atol=5e-5)


def test_checkpoint_roundtrip_exact():
    model, step_fn, loader, params, opt = setup()
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d)
        ck.save(3, {"params": params, "opt": opt}, extras={"note": "x"})
        state, step, extras, _ = ck.restore({"params": params, "opt": opt})
        assert step == 3 and extras["note"] == "x"
        for a, b in zip(jax.tree.leaves(state["params"]),
                        jax.tree.leaves(params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_restart_equals_uninterrupted():
    """Train 8 straight vs 4 + save + restore + 4: identical params."""
    model, step_fn, loader, params0, opt0 = setup(steps=8)

    def run(params, opt, lo, hi):
        for s in range(lo, hi):
            batch = {k: jnp.asarray(v) for k, v in loader.batch(s).items()}
            params, opt, _ = step_fn(params, opt, batch)
        return params, opt

    pA, oA = run(params0, opt0, 0, 8)
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d)
        pB, oB = run(params0, opt0, 0, 4)
        ck.save(4, {"params": pB, "opt": oB})
        state, step, _, _ = ck.restore({"params": pB, "opt": oB})
        pB, oB = run(state["params"], state["opt"], step, 8)
    for a, b in zip(jax.tree.leaves(pA), jax.tree.leaves(pB)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-6, atol=1e-7)


def test_checkpoint_gc_keeps_newest():
    model, *_ , params, opt = setup()
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d, keep=2)
        for s in (1, 2, 3, 4):
            ck.save(s, {"p": params})
        assert ck.list_steps() == [3, 4]


def test_straggler_monitor_and_remesh():
    from repro.distributed.elastic import StragglerMonitor, plan_remesh
    import time

    m = StragglerMonitor(threshold=5.0)
    for s in range(3):
        m.step_begin(); time.sleep(0.001); m.step_end(s)
    m.step_begin(); time.sleep(0.05)
    assert m.step_end(3) is True  # flagged as straggler
    # elastic re-mesh after losing devices
    assert plan_remesh(256, 16) == (16, 16)
    assert plan_remesh(192, 16) == (12, 16)
    assert plan_remesh(8, 16) == (1, 8)


def test_gradient_compression_roundtrip(rng):
    from repro.optim.compression import dequantize, quantize

    x = jnp.asarray(rng.standard_normal((1000,)) * 3.0, jnp.float32)
    q, scale, n = quantize(x)
    y = dequantize(q, scale, n, x.shape)
    rel = np.abs(np.asarray(y) - np.asarray(x)).max() / np.abs(np.asarray(x)).max()
    assert rel < 0.02  # int8 block quantization error bound
    assert q.dtype == jnp.int8
