"""Decentralized per-shard control plane: SRAM budgets, online shard
rebalancing, and fault-injection convergence.

The ISSUE 7 contract, layered on the PR 5 sharded rack:

* **Per-shard SRAM budgets** — ``ShardedRack(shard_slot_budgets=...)``
  gives every switch ASIC its own slot budget; capacity eviction goes
  *shard-local* (the victim pool is the overflowing shard's LRU only).
  Scalar and batched replays stay stat-, timing- and telemetry-event
  identical at 1/2/4 shards across every pressure regime, and a
  1-shard budget ``B`` is byte-identical to a plain rack with a global
  ``max_directory_entries=B`` cap.
* **Online rebalancing** — per-VA-block access counters accumulated at
  the home switch drive a deterministic greedy rebalancer at epoch
  boundaries: while the hottest shard exceeds ``threshold x`` the
  coldest, migrate the hottest blocks that strictly reduce the
  imbalance and fit the destination budget.  Migrated directory state
  moves via the per-shard snapshot row format and is charged
  ``entries_moved * switch_to_switch_us`` of stop-the-world time.  The
  known 75/25 XS skew at 2 shards flattens below 60/40.
* **Fault injection** — ``schedule_switch_kill(index, shard)`` drops
  shard *k*'s directory slice mid-trace and restores it from
  ``ControlPlane.snapshot(shard=k)``.  Because eviction only ever
  consults *within-shard* relative recency under budgets, the restored
  replay converges to the uninterrupted run's final stats and runtime
  exactly — on both engines, at any kill index.
"""

import json

import numpy as np
import pytest

from repro.core import faults as flt
from repro.core import traces as T
from repro.core.control_plane import ControlPlane
from repro.core.emulator import DisaggregatedRack, ShardedRack
from repro.core.switch import ShardMap
from repro.core.types import NetworkConstants
from repro.telemetry import Telemetry, canonical

STAT_FIELDS = (
    "accesses", "local_hits", "remote_fetches", "invalidations",
    "invalidated_pages", "false_invalidated_pages", "flushed_pages",
    "evicted_dirty", "evicted_clean", "faults",
)

ZERO_HOP = NetworkConstants(switch_to_switch_us=0.0)
HOP = NetworkConstants().switch_to_switch_us


def _assert_stats_equal(a, b, ctx=""):
    for f in STAT_FIELDS:
        assert getattr(a.stats, f) == getattr(b.stats, f), (ctx, f)


def _assert_timing_equal(a, b, ctx=""):
    np.testing.assert_allclose(b.runtime_us, a.runtime_us, rtol=1e-9,
                               err_msg=ctx)
    np.testing.assert_allclose(b.total_thread_us, a.total_thread_us,
                               rtol=1e-9, err_msg=ctx)
    for k, v in a.latency_breakdown_us.items():
        np.testing.assert_allclose(b.latency_breakdown_us[k], v, rtol=1e-6,
                                   err_msg=f"{ctx}:{k}")


# (max_directory_entries, cache_bytes, epoch_us or None, per-shard budget)
_REGIMES = {
    "plain": (30_000, 512 << 20, None, 4096),
    "dir_pressure": (30_000, 512 << 20, None, 24),
    "cache_pressure": (30_000, 1 << 14, None, 4096),
    "epochs": (30_000, 512 << 20, 2500.0, 4096),
    "cocktail": (30_000, 1 << 15, 2500.0, 32),
    "xs": (30_000, 512 << 20, 2500.0, 64),
}


def _trace(regime, seed=9, n=250):
    if regime == "xs":
        return T.sharded_conflict_trace(
            num_threads=4, accesses_per_thread=400, num_shards=4,
            blocks_per_shard=2, conflict_frac=0.5, write_frac=0.30,
            hot_pages_per_block=24, private_kb_per_thread=128, seed=seed)
    return T.sharded_conflict_trace(
        num_threads=4, accesses_per_thread=n, conflict_frac=0.5,
        write_frac=0.3, hot_pages_per_block=12, private_kb_per_thread=64,
        seed=seed)


def _rack_kw(regime, constants=ZERO_HOP):
    maxdir, cache_b, epoch, _budget = _REGIMES[regime]
    return dict(system="mind", num_compute_blades=2, threads_per_blade=2,
                max_directory_entries=maxdir,
                cache_bytes_per_blade=cache_b,
                splitting_enabled=epoch is not None,
                epoch_us=epoch or 10_000.0, constants=constants)


def _budgeted(regime, num_shards, engine, rebalance=False,
              constants=ZERO_HOP, telemetry=None):
    return ShardedRack(
        num_shards=num_shards, engine=engine,
        shard_slot_budgets=_REGIMES[regime][3],
        rebalance_threshold=1.5 if rebalance else None,
        telemetry=telemetry, **_rack_kw(regime, constants))


_runs = {}


def _run(regime, num_shards, engine, rebalance=False):
    """Cache one (trace, result, telemetry) per config: parity tests
    compare cached runs instead of re-running both engines per test."""
    key = (regime, num_shards, engine, rebalance)
    if key not in _runs:
        tel = Telemetry()
        rack = _budgeted(regime, num_shards, engine, rebalance,
                         telemetry=tel)
        _runs[key] = (rack.run(_trace(regime)), tel)
    return _runs[key]


# --------------------------------------------------------------------- #
# Per-shard budgets: scalar oracle == batched engine, all regimes.
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("num_shards", [1, 2, 4])
@pytest.mark.parametrize("regime", sorted(_REGIMES))
def test_budget_parity_scalar_vs_batched(regime, num_shards):
    """Shard-local eviction under per-ASIC budgets: the batched engine
    replays stat- and timing-identical to the shard-local scalar
    oracle at 1/2/4 shards in every pressure regime."""
    a, _ = _run(regime, num_shards, "scalar")
    b, _ = _run(regime, num_shards, "batched")
    _assert_stats_equal(a, b, f"{regime}/{num_shards}")
    _assert_timing_equal(a, b, f"{regime}/{num_shards}")
    assert b.directory_timeline == a.directory_timeline
    assert b.shard_accesses == a.shard_accesses
    assert b.cross_shard_accesses == a.cross_shard_accesses


@pytest.mark.parametrize("num_shards", [1, 2, 4])
@pytest.mark.parametrize("regime", sorted(_REGIMES))
def test_budget_parity_telemetry_events(regime, num_shards):
    """The full telemetry event streams (minus batched-only
    ``spec_rollback``) and counter registries agree too."""
    _, ta = _run(regime, num_shards, "scalar")
    _, tb = _run(regime, num_shards, "batched")
    ca = canonical(ta.recorder.events)
    cb = canonical(tb.recorder.events)
    assert [e.key() for e in ca] == [e.key() for e in cb]
    np.testing.assert_allclose([e.us for e in ca], [e.us for e in cb],
                               rtol=1e-6, atol=1e-9)
    skip = {"speculation_rollbacks_total"}
    counters = lambda t: {  # noqa: E731
        (r["name"], tuple(sorted(r["labels"].items()))): r["value"]
        for r in t.metrics.counters_to_jsonable() if r["name"] not in skip}
    assert counters(ta) == counters(tb)


@pytest.mark.parametrize("num_shards", [2, 4])
@pytest.mark.parametrize("regime", ["xs", "epochs", "cocktail"])
def test_rebalancer_parity_scalar_vs_batched(regime, num_shards):
    """With the online rebalancer enabled the two engines still agree
    exactly — on stats, timing, *and* the per-epoch migration reports
    (same blocks, same destinations, same charged microseconds)."""
    a, ta = _run(regime, num_shards, "scalar", rebalance=True)
    b, tb = _run(regime, num_shards, "batched", rebalance=True)
    _assert_stats_equal(a, b, f"{regime}/{num_shards}/rb")
    _assert_timing_equal(a, b, f"{regime}/{num_shards}/rb")
    assert b.rebalance_reports == a.rebalance_reports
    assert b.shard_accesses == a.shard_accesses
    ca, cb = canonical(ta.recorder.events), canonical(tb.recorder.events)
    assert [e.key() for e in ca] == [e.key() for e in cb]


@pytest.mark.parametrize("budget", [24, 64])
def test_one_shard_budget_equals_global_cap(budget):
    """A 1-shard rack under budget ``B`` is byte-identical to a plain
    single-switch rack with ``max_directory_entries=B``: the per-shard
    budget *replaces* the global capacity check."""
    kw = _rack_kw("plain")
    trace = _trace("plain")
    oracle = DisaggregatedRack(
        engine="scalar", **{**kw, "max_directory_entries": budget}).run(trace)
    r = ShardedRack(num_shards=1, engine="scalar", shard_slot_budgets=budget,
                    **kw).run(trace)
    _assert_stats_equal(oracle, r, f"budget={budget}")
    _assert_timing_equal(oracle, r, f"budget={budget}")
    assert r.directory_timeline == oracle.directory_timeline


@pytest.mark.parametrize("num_shards", [2, 4])
def test_shard_local_lru_matches_scan_oracle(num_shards):
    """Shard-local O(1) LRU eviction picks the exact victims the O(n)
    scan (coldest Invalid in the shard, else coldest overall) picks —
    the ISSUE 2 equivalence, extended to budgeted shard pools."""
    kw = _rack_kw("dir_pressure")
    trace = _trace("dir_pressure")
    runs = {}
    for ev in ("lru", "scan"):
        rack = ShardedRack(num_shards=num_shards, engine="scalar",
                           shard_slot_budgets=24, directory_eviction=ev, **kw)
        res = rack.run(trace)
        d = rack.mmu.engine.directory
        runs[ev] = (res, sorted(d.entries), d.capacity_evictions)
    _assert_stats_equal(runs["lru"][0], runs["scan"][0])
    assert runs["lru"][1] == runs["scan"][1]
    assert runs["lru"][2] == runs["scan"][2]


def test_budget_occupancy_never_exceeds_budget():
    """Invariant: no shard's slot count ever exceeds its budget (checked
    at the end of a pressured multi-epoch run, both engines)."""
    for engine in ("scalar", "batched"):
        rack = _budgeted("cocktail", 4, engine)
        rack.run(_trace("cocktail"))
        d = rack.mmu.engine.directory
        for s in range(4):
            assert d.shard_slots_used(s) <= d.shard_budgets[s], (engine, s)
        assert sorted(k for lru in d._shard_lru for k in lru) == \
            sorted(d.entries)


# --------------------------------------------------------------------- #
# Online rebalancer: the 75/25 XS split flattens, hops are exact.
# --------------------------------------------------------------------- #
def _issue_xs_trace():
    return T.sharded_conflict_trace(
        num_threads=4, accesses_per_thread=2000, num_shards=4,
        blocks_per_shard=2, block_log2=21, conflict_frac=0.5,
        write_frac=0.30, hot_pages_per_block=24,
        private_kb_per_thread=256, seed=9)


def test_rebalancer_flattens_xs_split():
    """The ISSUE's XS workload homes ~75% of its traffic at shard 0 of
    2.  With the rebalancer at threshold 1.5 the hot private blocks
    migrate out at the first epoch and the split flattens below 60/40,
    with every migration charged exactly ``entries * hop``."""
    trace = _issue_xs_trace()
    kw = dict(system="mind", num_compute_blades=2, threads_per_blade=2,
              max_directory_entries=30_000, epoch_us=2500.0,
              cache_bytes_per_blade=512 << 20, splitting_enabled=False)

    base = ShardedRack(num_shards=2, engine="scalar",
                       shard_slot_budgets=4096, **kw).run(trace)
    frac0 = base.shard_accesses[0] / sum(base.shard_accesses)
    assert frac0 > 0.70, base.shard_accesses  # the pinned skew
    assert base.rebalance_reports == []

    reb = ShardedRack(num_shards=2, engine="scalar", shard_slot_budgets=4096,
                      rebalance_threshold=1.5, **kw)
    res = reb.run(trace)
    frac = max(res.shard_accesses) / sum(res.shard_accesses)
    assert frac < 0.60, res.shard_accesses  # flattened
    assert res.rebalance_reports, "rebalancer never fired"
    for rp in res.rebalance_reports:
        assert rp["entries_moved"] == sum(m["entries"] for m in rp["moves"])
        np.testing.assert_allclose(rp["migration_us"],
                                   rp["entries_moved"] * HOP, rtol=1e-12)
        for m in rp["moves"]:
            assert m["from"] != m["to"]
    # Migrated homes are live: the overrides moved blocks off shard 0.
    assert reb.shard_map.overrides
    assert all(s == 1 for s in reb.shard_map.overrides.values())

    batched = ShardedRack(num_shards=2, engine="batched",
                          shard_slot_budgets=4096, rebalance_threshold=1.5,
                          **kw).run(trace)
    _assert_stats_equal(res, batched, "xs/rb")
    _assert_timing_equal(res, batched, "xs/rb")
    assert batched.rebalance_reports == res.rebalance_reports
    assert batched.shard_accesses == res.shard_accesses


def test_rebalance_telemetry_matches_reports():
    """Every migration emits one ``rebalance`` event whose fields and
    derived counters reproduce the report rows exactly."""
    tel = Telemetry()
    rack = ShardedRack(num_shards=2, engine="scalar", shard_slot_budgets=4096,
                       rebalance_threshold=1.5, telemetry=tel,
                       system="mind", num_compute_blades=2,
                       threads_per_blade=2, epoch_us=2500.0,
                       splitting_enabled=False)
    res = rack.run(_issue_xs_trace())
    moves = [m for rp in res.rebalance_reports for m in rp["moves"]]
    evs = [e for e in tel.recorder.events if e.kind == "rebalance"]
    assert len(evs) == len(moves) > 0
    lg = rack.shard_map.home_log2
    for e, m in zip(evs, moves):
        assert e.base == m["block"] << lg
        assert e.log2 == lg
        assert e.targets == m["to"]
        assert e.pages == m["entries"]
        np.testing.assert_allclose(e.us, m["entries"] * HOP, rtol=1e-12)
    counters = {(r["name"], tuple(sorted(r["labels"].items()))): r["value"]
                for r in tel.metrics.counters_to_jsonable()}
    for s in set(m["to"] for m in moves):
        assert counters[("rebalance_moves_total", (("shard", s),))] == \
            sum(1 for m in moves if m["to"] == s)
        assert counters[("rebalance_migrated_entries_total",
                         (("shard", s),))] == \
            sum(m["entries"] for m in moves if m["to"] == s)


def test_rebalance_charge_lands_in_runtime():
    """The stop-the-world migration charge is exact and isolated: with a
    zero hop, turning the rebalancer on under an already-running epoch
    driver changes *nothing* — migration is free and re-homing never
    changes a coherence transition or a charged microsecond; with the
    default hop every report charges exactly ``entries * hop``."""
    trace = _issue_xs_trace()
    kw = dict(system="mind", num_compute_blades=2, threads_per_blade=2,
              epoch_us=2500.0, splitting_enabled=True,
              shard_slot_budgets=4096)
    off = ShardedRack(num_shards=2, engine="scalar", constants=ZERO_HOP,
                      **kw).run(trace)
    on = ShardedRack(num_shards=2, engine="scalar", constants=ZERO_HOP,
                     rebalance_threshold=1.5, **kw).run(trace)
    _assert_stats_equal(off, on, "zero-hop")
    _assert_timing_equal(off, on, "zero-hop")
    assert on.rebalance_reports
    assert all(rp["migration_us"] == 0.0 for rp in on.rebalance_reports)

    on_hop = ShardedRack(num_shards=2, engine="scalar",
                         rebalance_threshold=1.5, **kw).run(trace)
    charged = sum(rp["migration_us"] for rp in on_hop.rebalance_reports)
    assert charged > 0
    for rp in on_hop.rebalance_reports:
        np.testing.assert_allclose(rp["migration_us"],
                                   rp["entries_moved"] * HOP, rtol=1e-12)
    # The charge lands on every thread clock at the epoch barrier: the
    # switch component of the breakdown grows by at least it.
    assert (on_hop.latency_breakdown_us["switch"]
            >= on_hop.cross_shard_accesses * HOP)


def test_rebalance_selection_deterministic_and_budget_aware():
    """Unit-level pin of the greedy move selection: hottest shard pays
    first, blocks move by descending access count (block id breaks
    ties), a move must strictly reduce the imbalance, and a destination
    without budget headroom is skipped."""
    rack = ShardedRack(num_shards=2, engine="scalar", shard_slot_budgets=64,
                       system="mind", num_compute_blades=2,
                       threads_per_blade=2, epoch_us=2500.0,
                       rebalance_threshold=1.5)
    rack.run(_trace("plain", n=50))  # populate some shard-0 state
    cp = rack.cp
    d = rack.mmu.engine.directory
    lg = rack.shard_map.home_log2
    blocks0 = sorted({k[0] >> lg for k in d.entries
                      if rack.shard_map.home_of_key(k) == 0})
    blocks1 = sorted({k[0] >> lg for k in d.entries
                      if rack.shard_map.home_of_key(k) == 1})
    assert len(blocks0) >= 2 and blocks1
    hot_a, hot_b = blocks0[0], blocks0[1]
    cold_blk = blocks1[0]
    # 80 vs 30: imbalanced past 1.5x; a single 40-count block is the
    # only candidate that *strictly reduces* the imbalance (0 < c <
    # diff), and hot_a wins the count tie on block id.
    counters = {hot_a: 40, hot_b: 40, cold_blk: 30}
    cp.rebalance_reports.clear()

    # No headroom at the destination: every entry-bearing hot block is
    # skipped — no report, no shard-map change.
    d.shard_budgets[1] = d.shard_slots_used(1)
    cp.block_accesses = dict(counters)
    cp._run_rebalance()
    assert cp.rebalance_reports == []
    assert rack.shard_map.overrides == {}
    assert cp.block_accesses == {}  # counters reset every epoch

    # With headroom, exactly one move: hot_a to the cold shard, after
    # which 40/70 is within threshold and the loop stops.
    d.shard_budgets[1] = 4096
    cp.block_accesses = dict(counters)
    cp._run_rebalance()
    rp = cp.rebalance_reports[-1]
    assert [m["block"] for m in rp["moves"]] == [hot_a]
    assert rp["moves"][0]["from"] == 0 and rp["moves"][0]["to"] == 1
    assert rp["moves"][0]["entries"] == sum(
        1 for k in d.entries if k[0] >> lg == hot_a)
    assert rack.shard_map.home_of(hot_a << lg) == 1
    assert rack.shard_map.overrides == {hot_a: 1}
    # Migrated entries are now in shard 1's local LRU.
    for k in d.entries:
        if k[0] >> lg == hot_a:
            assert k in d._shard_lru[1] and k not in d._shard_lru[0]

    # Already balanced (under threshold): no further moves.
    nrep = len(cp.rebalance_reports)
    cp.block_accesses = {hot_b: 11, cold_blk: 10}
    cp._run_rebalance()
    assert len(cp.rebalance_reports) == nrep


def test_shard_map_overrides_route_and_version():
    sm = ShardMap(num_shards=4, home_log2=21)
    v0 = sm.version
    sm.set_home(5, 2)
    assert sm.version == v0 + 1
    assert sm.home_of(5 << 21) == 2
    assert sm.home_of_key(((5 << 21) + (1 << 14), 14)) == 2
    vals = np.array([(5 << 21) + 7, (6 << 21) + 7, (9 << 21) + 7])
    np.testing.assert_array_equal(sm.home_of_batch(vals), [2, 2, 1])
    assert [sm.home_of(int(v)) for v in vals] == [2, 2, 1]
    # Reverting to the block-cyclic default drops the override.
    sm.set_home(5, 5 % 4)
    assert sm.overrides == {}
    assert sm.home_of(5 << 21) == 1


# --------------------------------------------------------------------- #
# Fault injection: kill switch k mid-trace, restore, converge.
# --------------------------------------------------------------------- #
_kill_kw = dict(system="mind", num_compute_blades=2, threads_per_blade=2,
                max_directory_entries=30_000, epoch_us=2500.0,
                cache_bytes_per_blade=512 << 20, splitting_enabled=True)


def _kill_run(engine, kill=None, **extra):
    rack = ShardedRack(num_shards=2, engine=engine, shard_slot_budgets=60,
                       rebalance_threshold=1.5, **_kill_kw, **extra)
    if kill is not None:
        rack.schedule_switch_kill(*kill)
    trace = T.sharded_conflict_trace(num_threads=4, accesses_per_thread=500,
                                     num_shards=4, blocks_per_shard=2, seed=9)
    return rack.run(trace)


@pytest.mark.parametrize("engine,kill_index,shard", [
    ("scalar", 1, 0), ("scalar", 137, 1), ("scalar", 500, 0),
    ("scalar", 999, 1), ("scalar", 1500, 0), ("scalar", 1999, 1),
    ("batched", 137, 0), ("batched", 500, 1), ("batched", 1500, 0),
])
def test_switch_kill_restore_converges(engine, kill_index, shard):
    """Kill switch *k* right before access ``kill_index`` (drop its
    whole directory slice), restore from the per-shard snapshot, and
    replay the rest of the trace: final stats, runtime and latency
    breakdown equal the uninterrupted run's — §3.2 failover with no
    replayed work."""
    base = _kill_run(engine)
    killed = _kill_run(engine, kill=(kill_index, shard))
    _assert_stats_equal(base, killed, f"{engine}@{kill_index}/s{shard}")
    _assert_timing_equal(base, killed, f"{engine}@{kill_index}/s{shard}")
    assert killed.shard_accesses == base.shard_accesses
    assert killed.rebalance_reports == base.rebalance_reports


def test_switch_kill_scalar_batched_agree_after_restore():
    killed_s = _kill_run("scalar", kill=(777, 1))
    killed_b = _kill_run("batched", kill=(777, 1))
    _assert_stats_equal(killed_s, killed_b, "post-restore parity")
    _assert_timing_equal(killed_s, killed_b, "post-restore parity")


def test_schedule_switch_kill_validates_arguments():
    rack = ShardedRack(num_shards=2, system="mind", num_compute_blades=2,
                       threads_per_blade=2)
    with pytest.raises(ValueError, match="negative access index"):
        rack.schedule_switch_kill(-1, 0)
    with pytest.raises(ValueError, match="unknown shard"):
        rack.schedule_switch_kill(0, 2)


def _plan_run(engine, plan):
    rack = ShardedRack(num_shards=2, engine=engine, shard_slot_budgets=60,
                       rebalance_threshold=1.5, **_kill_kw)
    rack.schedule_fault_plan(plan)
    trace = T.sharded_conflict_trace(num_threads=4, accesses_per_thread=500,
                                     num_shards=4, blocks_per_shard=2, seed=9)
    return rack.run(trace)


@pytest.mark.parametrize("engine", ["scalar", "batched"])
def test_repeated_switch_kill_cycles_converge(engine):
    """The generalized fault schedule replaces the single-shot
    ``_kill_at``: kill -> restore -> kill the same shard (and the other)
    repeatedly, with the online rebalancer live, and the replay still
    converges exactly to the uninterrupted run."""
    plan = [flt.FaultEvent(100, flt.SWITCH_KILL, 0),
            flt.FaultEvent(101, flt.SWITCH_KILL, 0),
            flt.FaultEvent(750, flt.SWITCH_KILL, 1),
            flt.FaultEvent(1400, flt.SWITCH_KILL, 0),
            flt.FaultEvent(1999, flt.SWITCH_KILL, 1)]
    base = _kill_run(engine)
    killed = _plan_run(engine, plan)
    _assert_stats_equal(base, killed, f"{engine} repeated kills")
    _assert_timing_equal(base, killed, f"{engine} repeated kills")
    assert killed.rebalance_reports == base.rebalance_reports
    assert [f.kind for f in killed.fault_reports] == [flt.SWITCH_KILL] * 5
    assert all(f.entries_restored >= 0 for f in killed.fault_reports)


def test_mixed_blade_and_switch_faults_on_sharded_rack():
    """Blade faults and switch failovers interleave in one schedule; the
    two engines agree on stats, timing and the per-fault reports."""
    plan = [flt.FaultEvent(200, flt.BLADE_KILL, 0),
            flt.FaultEvent(600, flt.SWITCH_KILL, 1),
            flt.FaultEvent(900, flt.BLADE_RESTORE, 0),
            flt.FaultEvent(1300, flt.BLADE_KILL, 1),
            flt.FaultEvent(1700, flt.SWITCH_KILL, 0)]
    rs = _plan_run("scalar", plan)
    rb = _plan_run("batched", plan)
    _assert_stats_equal(rs, rb, "mixed faults parity")
    _assert_timing_equal(rs, rb, "mixed faults parity")
    assert rs.fault_reports == rb.fault_reports
    assert [f.kind for f in rs.fault_reports] == [
        flt.BLADE_KILL, flt.SWITCH_KILL, flt.BLADE_RESTORE,
        flt.BLADE_KILL, flt.SWITCH_KILL]
    base = _kill_run("scalar")
    _assert_stats_equal(base, rs, "mixed faults converge")
    _assert_timing_equal(base, rs, "mixed faults converge")


# --------------------------------------------------------------------- #
# snapshot(shard=k) / restore round trip.
# --------------------------------------------------------------------- #
def test_snapshot_shard_without_map_raises_value_error():
    """The pinned ISSUE 7 bug fix: asking a single-switch control plane
    for a per-shard snapshot is a usage error with a clear message, not
    an assert."""
    rack = DisaggregatedRack(system="mind", num_compute_blades=2,
                             threads_per_blade=2)
    with pytest.raises(ValueError, match="requires a shard map"):
        rack.cp.snapshot(shard=0)


def test_snapshot_shard_out_of_range_raises_value_error():
    rack = ShardedRack(num_shards=2, system="mind", num_compute_blades=2,
                       threads_per_blade=2)
    with pytest.raises(ValueError, match="out of range"):
        rack.cp.snapshot(shard=2)
    with pytest.raises(ValueError, match="out of range"):
        rack.cp.snapshot(shard=-1)


def test_restore_shard_requires_shard_scoped_snapshot():
    rack = ShardedRack(num_shards=2, system="mind", num_compute_blades=2,
                       threads_per_blade=2)
    rack.run(_trace("plain", n=40))
    with pytest.raises(ValueError):
        rack.cp.restore_shard(rack.cp.snapshot())  # full, not per-shard


@pytest.mark.parametrize("shard", [0, 1])
def test_snapshot_shard_roundtrip_preserves_lru_and_stats(shard):
    """Kill-and-restore round trip through the per-shard snapshot: the
    shard's entry set, within-shard LRU order, §4.4 prepopulation marks
    and per-region counters all survive."""
    rack = _budgeted("dir_pressure", 2, "scalar")
    rack.run(_trace("dir_pressure"))
    d = rack.mmu.engine.directory
    sm = rack.shard_map
    before = [k for k in d.lru_keys() if sm.home_of_key(k) == shard]
    ent_before = {k: (d.entries[k].state, d.entries[k].sharers,
                      d.entries[k].owner) for k in before}
    stats_before = {k: (d.stats[k].false_invalidations, d.stats[k].accesses)
                    for k in before}
    prepop_before = {k for k in rack.mmu.engine._prepopulated
                     if sm.home_of_key(k) == shard}
    other = [k for k in d.lru_keys() if sm.home_of_key(k) != shard]

    n = rack.kill_and_restore_switch(shard)
    assert n == len(before)
    after = [k for k in d.lru_keys() if sm.home_of_key(k) == shard]
    assert after == before  # within-shard relative LRU order survives
    assert [k for k in d.lru_keys() if sm.home_of_key(k) != shard] == other
    for k in before:
        e = d.entries[k]
        assert (e.state, e.sharers, e.owner) == ent_before[k]
        assert (d.stats[k].false_invalidations,
                d.stats[k].accesses) == stats_before[k]
    assert {k for k in rack.mmu.engine._prepopulated
            if sm.home_of_key(k) == shard} == prepop_before
    # Shard lists were rebuilt consistently.
    assert list(d._shard_lru[shard]) == after


def test_snapshot_shard_telemetry_slice_roundtrip():
    """A per-shard snapshot carries exactly that shard's counter slice
    (`counters_to_jsonable(shard=k)`), and a fresh-restored rack evicts
    the same victims as the original — eviction state is fully
    captured."""
    tel = Telemetry()
    rack = _budgeted("dir_pressure", 2, "scalar", telemetry=tel)
    trace = _trace("dir_pressure")
    rack.run(trace)
    snap = json.loads(rack.cp.snapshot(shard=1))
    assert snap["shards"]["shard"] == 1
    assert snap["telemetry"] == tel.metrics.counters_to_jsonable(shard=1)

    # Post-restore eviction behavior: a twin rack that was killed and
    # restored mid-run picks the same capacity victims afterwards.
    twin = _budgeted("dir_pressure", 2, "scalar")
    twin.schedule_switch_kill(400, 1)
    twin.run(trace)
    d0, d1 = rack.mmu.engine.directory, twin.mmu.engine.directory
    v0 = [d0.evict_for_capacity(queue_pending=False, shard=1)
          for _ in range(min(5, d0.shard_slots_used(1)))]
    v1 = [d1.evict_for_capacity(queue_pending=False, shard=1)
          for _ in range(min(5, d1.shard_slots_used(1)))]
    assert [(e.base, e.size_log2) for e in v0] == \
        [(e.base, e.size_log2) for e in v1]


# --------------------------------------------------------------------- #
# Property suites (hypothesis).
# --------------------------------------------------------------------- #
try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised via CI extra install
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 2 ** 31),
           regime=st.sampled_from(sorted(_REGIMES)),
           num_shards=st.sampled_from([1, 2, 4]),
           rebalance=st.booleans())
    def test_budget_scalar_replay_deterministic_hypothesis(
            seed, regime, num_shards, rebalance):
        """Random traces under per-shard budgets: two identical scalar
        replays agree exactly (determinism), occupancy respects every
        budget, and migration accounting stays exact."""
        trace = _trace(regime, seed=seed, n=150)
        results = []
        for _ in range(2):
            rack = _budgeted(regime, num_shards, "scalar",
                             rebalance=rebalance)
            res = rack.run(trace)
            d = rack.mmu.engine.directory
            for s in range(num_shards):
                assert d.shard_slots_used(s) <= d.shard_budgets[s]
            for rp in res.rebalance_reports:
                assert rp["migration_us"] == 0.0  # ZERO_HOP configs
            results.append(res)
        _assert_stats_equal(results[0], results[1], regime)
        _assert_timing_equal(results[0], results[1], regime)
        assert results[0].rebalance_reports == results[1].rebalance_reports

    @settings(max_examples=5, deadline=None)
    @given(seed=st.integers(0, 2 ** 31),
           regime=st.sampled_from(["dir_pressure", "cocktail", "xs"]))
    def test_budget_batched_matches_scalar_hypothesis(seed, regime):
        trace = _trace(regime, seed=seed, n=150)
        a = _budgeted(regime, 2, "scalar", rebalance=True).run(trace)
        b = _budgeted(regime, 2, "batched", rebalance=True).run(trace)
        _assert_stats_equal(a, b, regime)
        _assert_timing_equal(a, b, regime)
        assert b.rebalance_reports == a.rebalance_reports

    @settings(max_examples=12, deadline=None)
    @given(seed=st.integers(0, 2 ** 31),
           kill_frac=st.floats(0.0, 1.0),
           shard=st.integers(0, 1),
           regime=st.sampled_from(["dir_pressure", "epochs", "cocktail"]))
    def test_switch_kill_converges_hypothesis(seed, kill_frac, shard,
                                              regime):
        """Mid-trace switch kill at a randomized index converges to the
        uninterrupted replay under budgets, splitting and rebalancing."""
        trace = _trace(regime, seed=seed, n=150)
        n = len(trace.accesses)
        idx = min(n - 1, int(kill_frac * n))
        base_rack = _budgeted(regime, 2, "scalar", rebalance=True)
        base = base_rack.run(trace)
        killed_rack = _budgeted(regime, 2, "scalar", rebalance=True)
        killed_rack.schedule_switch_kill(idx, shard)
        killed = killed_rack.run(trace)
        _assert_stats_equal(base, killed, f"{regime}@{idx}")
        _assert_timing_equal(base, killed, f"{regime}@{idx}")
        assert killed.rebalance_reports == base.rebalance_reports

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2 ** 31), shard=st.integers(0, 1))
    def test_snapshot_shard_roundtrip_hypothesis(seed, shard):
        """snapshot(shard=k) -> restore_shard round trip on random
        budgeted runs: entry set, shard LRU order, prepop marks and the
        subsequent eviction sequence are all preserved."""
        trace = _trace("dir_pressure", seed=seed, n=150)
        rack = _budgeted("dir_pressure", 2, "scalar")
        rack.run(trace)
        d = rack.mmu.engine.directory
        sm = rack.shard_map
        before = [k for k in d.lru_keys() if sm.home_of_key(k) == shard]
        n = rack.kill_and_restore_switch(shard)
        assert n == len(before)
        after = [k for k in d.lru_keys() if sm.home_of_key(k) == shard]
        assert after == before
        twin = _budgeted("dir_pressure", 2, "scalar")
        twin.run(trace)
        d2 = twin.mmu.engine.directory
        while d.shard_slots_used(shard):
            a = d.evict_for_capacity(queue_pending=False, shard=shard)
            b = d2.evict_for_capacity(queue_pending=False, shard=shard)
            assert (a.base, a.size_log2) == (b.base, b.size_log2)
